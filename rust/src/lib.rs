//! # linear-sinkhorn
//!
//! A production-shaped reproduction of **"Linear Time Sinkhorn Divergences
//! using Positive Features"** (Scetbon & Cuturi, NeurIPS 2020).
//!
//! The paper's idea: instead of choosing a cost `c` and deriving the Gibbs
//! kernel `K = exp(-C/eps)`, choose a *positive feature map*
//! `phi: X -> (R_+^*)^r` and define `k(x,y) = <phi(x), phi(y)>`. Then
//! `K = xi^T zeta` is factorised by construction, every Sinkhorn iteration
//! costs `O(r(n+m))` instead of `O(nm)`, and — unlike Nyström low-rank
//! approximations — positivity of `Kv` is guaranteed for any `r`.
//!
//! ## Architecture (three layers)
//!
//! * **L1 (Pallas, build-time python)** — tiled feature-map and factored
//!   matvec kernels, `python/compile/kernels/`.
//! * **L2 (JAX, build-time python)** — Sinkhorn compute graphs AOT-lowered
//!   to HLO text artifacts, `python/compile/model.py` + `aot.py`.
//! * **L3 (this crate)** — coordinator, native algorithm suite, PJRT
//!   runtime that loads the artifacts, service, GAN trainer, benches.
//!
//! Python never runs on the request path: `make artifacts` is the only
//! python invocation, and the binary is self-contained afterwards.
//!
//! The cross-cutting L3 subsystems (see README.md and EXPERIMENTS.md
//! §Perf / §Parallel scaling / §Stabilisation):
//!
//! * [`linalg::simd`] — the SIMD core: every hot kernel (matvecs, fused
//!   batch applies, logsumexp reductions, feature-evaluation dots)
//!   dispatches at runtime between an AVX2+FMA intrinsics arm — with
//!   the vectorised ≤ 2 ulp `exp`/`ln` of [`special::vexp`] on the
//!   log-domain path — and the portable scalar arm
//!   (`LINEAR_SINKHORN_SIMD=scalar` forces it). Bitwise
//!   thread-count-determinism holds per arm.
//! * [`runtime::pool`] — the intra-solve parallel execution layer, a
//!   persistent channel-fed worker pool behind the row-chunked pooled
//!   matvecs and logsumexp reductions ([`linalg`]), parallel feature
//!   evaluation ([`features::par_feature_matrix`]) and the concurrent
//!   three-problem divergence ([`sinkhorn::sinkhorn_divergence`]),
//!   all deterministic in the thread count.
//! * [`kernels::LogKernelOp`] — the matrix-free log-domain operator
//!   behind [`sinkhorn::sinkhorn_log_domain`]: small-eps stabilisation
//!   that stays O(r(n+m)) on factored kernels, with automatic
//!   escalation from plain Alg. 1 ([`sinkhorn::sinkhorn_stabilized`],
//!   `sinkhorn.stabilize`).
//! * [`coordinator::cache`] — the shared `(dim, eps, r)`-keyed
//!   feature-map cache that amortises the Lemma-1 anchor draw across
//!   requests, with hit/miss counters in [`metrics`].
//! * [`sinkhorn::solve_batch`] — the batched multi-pair solve engine:
//!   B transport problems sharing one kernel iterate as column-blocked
//!   scaling matrices with fused `Φ_x(Φ_y^T V)` mat-mat applies, bitwise
//!   identical to B sequential solves; the coordinator fuses compatible
//!   in-flight requests onto it (`sinkhorn.max_batch`,
//!   `service.batched_solves`; EXPERIMENTS.md §Throughput).
//! * [`session`] — streaming sessions for long-lived *mutating*
//!   measures: Φ maintained incrementally (O(r) per inserted / evicted /
//!   swapped point — the factored kernel is append-only along n for a
//!   fixed map), duals cached and remapped across updates so queries
//!   warm-start in a handful of iterations, served through the
//!   coordinator's session table and the sharded tier's resident
//!   per-session Φ replicas (README.md §Streaming sessions).
//! * [`shard`] — cross-host sharded serving: fuse groups scatter over
//!   in-process or TCP workers as binary wire envelopes
//!   ([`runtime::wire`], [`api::envelope`]) and gather bitwise identical
//!   to the single-host fused solve, with heartbeat liveness, bounded
//!   retry + re-scatter, and a deterministic fault-injection harness
//!   ([`shard::testing`]; README.md §Sharded serving).
//!
//! ## Quick tour: Problem → Plan → Solution
//!
//! The blessed entry point is the planned API ([`api`]): describe the
//! problem, let the planner pick the backend (the paper's factored
//! kernel vs the dense baseline, by per-iteration flops) and the
//! numeric domain (plain f32 vs log-domain stabilisation, by the
//! f32-underflow heuristic), then execute.
//!
//! ```no_run
//! use linear_sinkhorn::prelude::*;
//!
//! // Two point clouds.
//! let mut rng = Rng::seed_from(0);
//! let (mu, nu) = data::gaussian_blobs(1000, &mut rng);
//!
//! // Describe the problem; the planner decides the rest.
//! let problem = OtProblem::new(&mu, &nu).epsilon(0.5).rank(256).seed(0);
//! let plan = problem.plan()?;
//! println!("{}", plan.summary()); // inspectable decision record
//!
//! // Linear-time Sinkhorn through the planned route.
//! let sol = problem.solve_planned(&plan)?;
//! println!("ROT ~= {}  [{} iters, arm {}]", sol.objective, sol.iterations, sol.simd_arm);
//!
//! // The debiased Eq. (2) divergence (three solves, one shared map).
//! let report = problem.divergence()?;
//! println!("divergence = {}", report.divergence);
//! # Ok::<(), linear_sinkhorn::error::Error>(())
//! ```
//!
//! Plans serialise ([`api::Plan::to_json`]) and execute anywhere
//! ([`api::OtProblem::solve_planned`]) — the unit of the planned
//! cross-host shard dispatch. The pre-API free functions
//! (`sinkhorn`, `sinkhorn_divergence`, `solve_batch`, …) remain as the
//! reference layer the executor routes through bitwise-unchanged;
//! import them explicitly via [`prelude::legacy`] (see README.md
//! §Migration for the mapping).

pub mod api;
pub mod barycenter;
pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod features;
pub mod gan;
pub mod kernels;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod session;
pub mod shard;
pub mod sinkhorn;
pub mod special;
pub mod testing;

/// Convenient re-exports for examples and downstream users.
///
/// The prelude exports the planned API ([`crate::api`]) plus the
/// data/kernel/config vocabulary. The pre-API free-function solvers are
/// **not** re-exported wholesale any more — they live in
/// [`prelude::legacy`], so downstream code migrates by replacing
/// `use linear_sinkhorn::prelude::*;` call sites with
/// `OtProblem`-builder forms at its own pace, opting into the old names
/// explicitly (and warning-free) where it still needs them.
pub mod prelude {
    pub use crate::api::{
        Backend, BackendPref, DivergenceReport, Domain, DomainChoice, KernelChoice, OtProblem,
        Plan, SimdPreference, Solution,
    };
    pub use crate::config::{GanConfig, ServiceConfig, SinkhornConfig, TradeoffConfig};
    pub use crate::data::{self, Measure};
    pub use crate::error::{Error, Result};
    pub use crate::features::{ArcCosFeatureMap, FeatureMap, GaussianFeatureMap};
    pub use crate::kernels::{
        CostMatrixLogKernel, DenseKernel, FactoredKernel, KernelOp, LogKernelOp, NystromKernel,
    };
    pub use crate::linalg::Mat;
    pub use crate::rng::Rng;
    pub use crate::runtime::pool::Pool;
    pub use crate::session::{QueryReport, SessionConfig, SessionOp, StreamingSession};
    pub use crate::sinkhorn::{EpsSchedule, SinkhornSolution};

    /// The pre-API free-function solver surface, demoted to an explicit
    /// opt-in. These are the reference implementations the planned
    /// executor routes through bitwise-unchanged (and the baseline the
    /// equivalence suite compares against) — prefer
    /// [`OtProblem`](super::OtProblem) for new code; see README.md
    /// §Migration for the entry-point mapping.
    pub mod legacy {
        pub use crate::sinkhorn::{
            sinkhorn, sinkhorn_accelerated, sinkhorn_divergence, sinkhorn_divergence_batch,
            sinkhorn_log_domain, sinkhorn_stabilized, sinkhorn_symmetric,
            sinkhorn_symmetric_log, sinkhorn_symmetric_stabilized, solve_batch,
            solve_batch_log_domain, solve_batch_stabilized, SinkhornSolution,
        };
    }
}
