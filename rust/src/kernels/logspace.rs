//! Log-domain kernel operators — the object *stabilised* Sinkhorn
//! iterates against.
//!
//! Log-domain Sinkhorn never forms the scalings `u, v` (which over/
//! underflow at small eps); its updates are row/column logsumexp
//! reductions of `log K + input`. [`LogKernelOp`] abstracts exactly that
//! pair of reductions, so the same generic solver
//! ([`crate::sinkhorn::sinkhorn_log_domain`]) runs:
//!
//! * the dense `Sin` baseline at O(nm)/update, streaming `-cost/eps`
//!   ([`DenseKernel`] keeps its cost matrix for this), and
//! * the paper's `RF` factored kernel at **O(r(n+m))/update and memory**,
//!   nesting the logsumexp through the factorisation
//!   (`log K_ij = logsumexp_k(lx_ik + ly_jk)`) without ever materialising
//!   an n×m matrix — the linear-time claim survives stabilisation.
//!
//! All reductions run through the chunk-gridded f64 primitives in
//! [`crate::linalg`] (`lse_matvec*`), which are thread-count-
//! deterministic over the shared worker pool like every other pooled
//! kernel in this crate (EXPERIMENTS.md §Stabilisation, §Parallel
//! scaling).

use crate::linalg::{
    lse_matmat_into, lse_matmat_into_pooled, lse_matmat_t_into, lse_matmat_t_into_pooled,
    lse_matvec_into, lse_matvec_into_pooled, lse_matvec_t_into, lse_matvec_t_into_pooled, Mat,
};

use super::{DenseKernel, FactoredKernel};

/// Matrix-free log-domain kernel operator: streamed logsumexp of
/// `log K + input` over rows or columns.
///
/// Method names are disjoint from [`super::KernelOp`] so types may
/// implement both without call-site ambiguity.
pub trait LogKernelOp {
    /// (rows, cols) of K.
    fn shape(&self) -> (usize, usize);

    /// `out[i] = logsumexp_j(log K_ij + t[j])` (length rows).
    fn apply_log(&self, t: &[f64], out: &mut [f64]);

    /// `out[j] = logsumexp_i(log K_ij + u[i])` (length cols).
    fn apply_log_t(&self, u: &[f64], out: &mut [f64]);

    /// Column-blocked [`LogKernelOp::apply_log`]: one input/output vector
    /// per pair. The default loops the vector apply; fused overrides must
    /// stay **bitwise identical per pair** to it — the contract the
    /// batched log-domain solver
    /// ([`crate::sinkhorn::solve_batch_log_domain`]) relies on for its
    /// sequential-equivalence guarantee.
    fn apply_log_batch(&self, ts: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        for (t, o) in ts.iter().zip(outs.iter_mut()) {
            self.apply_log(t, o);
        }
    }

    /// Column-blocked [`LogKernelOp::apply_log_t`]; same contract as
    /// [`LogKernelOp::apply_log_batch`].
    fn apply_log_batch_t(&self, us: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        for (u, o) in us.iter().zip(outs.iter_mut()) {
            self.apply_log_t(u, o);
        }
    }

    /// Human-readable label for reports and error messages.
    fn describe(&self) -> String;
}

/// A borrowed cost matrix as a log kernel: `log K = -cost/eps`. The
/// cheap adapter for callers that hold a cost matrix and want the
/// log-domain solver without building a [`DenseKernel`] (e.g. the
/// tradeoff benches' small-eps ground truth).
pub struct CostMatrixLogKernel<'a> {
    cost: &'a Mat,
    eps: f64,
}

impl<'a> CostMatrixLogKernel<'a> {
    pub fn new(cost: &'a Mat, eps: f64) -> Self {
        assert!(eps > 0.0 && eps.is_finite(), "eps must be positive");
        CostMatrixLogKernel { cost, eps }
    }
}

impl LogKernelOp for CostMatrixLogKernel<'_> {
    fn shape(&self) -> (usize, usize) {
        self.cost.shape()
    }

    fn apply_log(&self, t: &[f64], out: &mut [f64]) {
        lse_matvec_into(self.cost, -1.0 / self.eps, t, out);
    }

    fn apply_log_t(&self, u: &[f64], out: &mut [f64]) {
        lse_matvec_t_into(self.cost, -1.0 / self.eps, u, out);
    }

    fn describe(&self) -> String {
        let (n, m) = self.cost.shape();
        format!("cost-matrix log kernel ({n}x{m}, eps={})", self.eps)
    }
}

impl LogKernelOp for DenseKernel {
    fn shape(&self) -> (usize, usize) {
        self.k.shape()
    }

    /// Streams the retained *unfloored* cost: exact where `k` itself has
    /// flushed to the `exp(LOG_FLOOR)` positivity floor.
    fn apply_log(&self, t: &[f64], out: &mut [f64]) {
        lse_matvec_into(&self.cost, -1.0 / self.eps, t, out);
    }

    fn apply_log_t(&self, u: &[f64], out: &mut [f64]) {
        lse_matvec_t_into(&self.cost, -1.0 / self.eps, u, out);
    }

    /// Fused multi-pair form: one stream over the cost matrix serves all
    /// B pairs (bitwise identical per pair to [`LogKernelOp::apply_log`]).
    fn apply_log_batch(&self, ts: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        lse_matmat_into(&self.cost, -1.0 / self.eps, ts, outs);
    }

    fn apply_log_batch_t(&self, us: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        lse_matmat_t_into(&self.cost, -1.0 / self.eps, us, outs);
    }

    fn describe(&self) -> String {
        let (n, m) = self.k.shape();
        format!("Sin-log(dense {n}x{m})")
    }
}

impl LogKernelOp for FactoredKernel {
    fn shape(&self) -> (usize, usize) {
        (self.phi_x.rows(), self.phi_y.rows())
    }

    /// `logsumexp_j(log K_ij + t_j)` through the factorisation:
    ///
    /// ```text
    /// log K_ij = logsumexp_k(lx_ik + ly_jk)          (raw log factors)
    /// out_i    = logsumexp_k(lx_ik + s_k),  s_k = logsumexp_j(ly_jk + t_j)
    /// ```
    ///
    /// Two skinny logsumexp matvecs — O(r(n+m)) time, O(r) extra memory,
    /// no n×m intermediate — routed through the kernel's worker pool.
    /// Exact in exact arithmetic (sums re-associate); in f64 it matches a
    /// dense reduction of the same log factors to ~1e-12.
    fn apply_log(&self, t: &[f64], out: &mut [f64]) {
        let (lx, ly) = self.log_factors();
        let mut s = vec![0.0f64; self.rank()];
        lse_matvec_t_into_pooled(ly, 1.0, t, &mut s, &self.pool);
        lse_matvec_into_pooled(lx, 1.0, &s, out, &self.pool);
    }

    fn apply_log_t(&self, u: &[f64], out: &mut [f64]) {
        let (lx, ly) = self.log_factors();
        let mut s = vec![0.0f64; self.rank()];
        lse_matvec_t_into_pooled(lx, 1.0, u, &mut s, &self.pool);
        lse_matvec_into_pooled(ly, 1.0, &s, out, &self.pool);
    }

    /// Fused multi-pair nested logsumexp: the inner and outer reductions
    /// run column-blocked, streaming each log factor once for all B pairs
    /// — O(r(n+m)) per pair, O(B·r) intermediate, and bitwise identical
    /// per pair to [`LogKernelOp::apply_log`] at every pool size (the
    /// column-blocked primitives share kernels and chunk grids with the
    /// vector ones).
    fn apply_log_batch(&self, ts: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        let (lx, ly) = self.log_factors();
        let mut ss: Vec<Vec<f64>> = (0..ts.len()).map(|_| vec![0.0f64; self.rank()]).collect();
        lse_matmat_t_into_pooled(ly, 1.0, ts, &mut ss, &self.pool);
        lse_matmat_into_pooled(lx, 1.0, &ss, outs, &self.pool);
    }

    fn apply_log_batch_t(&self, us: &[Vec<f64>], outs: &mut [Vec<f64>]) {
        let (lx, ly) = self.log_factors();
        let mut ss: Vec<Vec<f64>> = (0..us.len()).map(|_| vec![0.0f64; self.rank()]).collect();
        lse_matmat_t_into_pooled(lx, 1.0, us, &mut ss, &self.pool);
        lse_matmat_into_pooled(ly, 1.0, &ss, outs, &self.pool);
    }

    fn describe(&self) -> String {
        let (n, m) = LogKernelOp::shape(self);
        format!("RF-log(r={} {n}x{m})", self.rank())
    }
}

#[cfg(test)]
mod tests {
    use super::super::KernelOp;
    use super::*;
    use crate::data;
    use crate::features::{FeatureMap, GaussianFeatureMap};
    use crate::rng::Rng;

    /// Dense f64 reference: out_i = logsumexp_j(log_k[i][j] + t_j).
    fn reference_apply_log(log_k: &[Vec<f64>], t: &[f64]) -> Vec<f64> {
        log_k
            .iter()
            .map(|row| {
                let m = row
                    .iter()
                    .zip(t)
                    .map(|(&l, &tj)| l + tj)
                    .fold(f64::NEG_INFINITY, f64::max);
                if !m.is_finite() {
                    return m;
                }
                m + row
                    .iter()
                    .zip(t)
                    .map(|(&l, &tj)| (l + tj - m).exp())
                    .sum::<f64>()
                    .ln()
            })
            .collect()
    }

    /// Materialise log K of a factored kernel from its raw log factors.
    fn dense_log_kernel(lx: &Mat, ly: &Mat) -> Vec<Vec<f64>> {
        let (n, r) = lx.shape();
        let m = ly.rows();
        (0..n)
            .map(|i| {
                (0..m)
                    .map(|j| {
                        let terms: Vec<f64> = (0..r)
                            .map(|k| lx[(i, k)] as f64 + ly[(j, k)] as f64)
                            .collect();
                        let mx = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                        mx + terms.iter().map(|&v| (v - mx).exp()).sum::<f64>().ln()
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn dense_apply_log_matches_reference() {
        let mut rng = Rng::seed_from(0);
        let (mu, nu) = data::gaussian_blobs(20, &mut rng);
        let eps = 0.3;
        let dk = DenseKernel::from_measures(&mu, &nu, eps);
        let log_k: Vec<Vec<f64>> = (0..20)
            .map(|i| (0..20).map(|j| -(dk.cost()[(i, j)] as f64) / eps).collect())
            .collect();
        let t: Vec<f64> = (0..20).map(|j| (j as f64) * 0.1 - 1.0).collect();
        let mut out = vec![0.0f64; 20];
        LogKernelOp::apply_log(&dk, &t, &mut out);
        let want = reference_apply_log(&log_k, &t);
        for i in 0..20 {
            assert!((out[i] - want[i]).abs() < 1e-12, "row {i}");
        }
        // Transposed: compare against the transposed reference.
        let log_k_t: Vec<Vec<f64>> =
            (0..20).map(|j| (0..20).map(|i| log_k[i][j]).collect()).collect();
        let mut out_t = vec![0.0f64; 20];
        LogKernelOp::apply_log_t(&dk, &t, &mut out_t);
        let want_t = reference_apply_log(&log_k_t, &t);
        for j in 0..20 {
            assert!((out_t[j] - want_t[j]).abs() < 1e-12, "col {j}");
        }
    }

    #[test]
    fn factored_apply_log_matches_materialised_log_kernel() {
        // The factored nested-logsumexp path against a dense f64
        // materialisation of the same log kernel — at an eps small enough
        // that the *exponentiated* factors are useless (clamped), which
        // is exactly the regime the log path exists for.
        let mut rng = Rng::seed_from(1);
        let (mu, nu) = data::gaussian_blobs(15, &mut rng);
        let eps = 1e-3;
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, 24, &mut rng);
        let lx = map.log_feature_matrix(&mu.points);
        let ly = map.log_feature_matrix(&nu.points);
        let fk = FactoredKernel::from_log_factors(lx.clone(), ly.clone());
        let log_k = dense_log_kernel(&lx, &ly);

        let t: Vec<f64> = (0..15).map(|j| (j as f64) * 2.0 - 10.0).collect();
        let mut out = vec![0.0f64; 15];
        LogKernelOp::apply_log(&fk, &t, &mut out);
        let want = reference_apply_log(&log_k, &t);
        for i in 0..15 {
            let rel = (out[i] - want[i]).abs() / want[i].abs().max(1.0);
            assert!(rel < 1e-10, "row {i}: {} vs {}", out[i], want[i]);
        }

        let log_k_t: Vec<Vec<f64>> =
            (0..15).map(|j| (0..15).map(|i| log_k[i][j]).collect()).collect();
        let mut out_t = vec![0.0f64; 15];
        LogKernelOp::apply_log_t(&fk, &t, &mut out_t);
        let want_t = reference_apply_log(&log_k_t, &t);
        for j in 0..15 {
            let rel = (out_t[j] - want_t[j]).abs() / want_t[j].abs().max(1.0);
            assert!(rel < 1e-10, "col {j}");
        }
    }

    #[test]
    fn factored_log_view_consistent_with_plain_applies_at_moderate_eps() {
        // Where nothing clamps, exp(apply_log(log v)) must equal the
        // plain apply (up to f32-vs-f64 rounding): the two views are the
        // same operator.
        let mut rng = Rng::seed_from(2);
        let (mu, nu) = data::gaussian_blobs(25, &mut rng);
        let eps = 1.0;
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, 32, &mut rng);
        let fk = FactoredKernel::from_measures_stabilized(&map, &mu, &nu);
        let v: Vec<f32> = (0..25).map(|j| 0.2 + 0.01 * j as f32).collect();
        let plain = fk.apply(&v);
        let log_v: Vec<f64> = v.iter().map(|&x| (x as f64).ln()).collect();
        let mut log_out = vec![0.0f64; 25];
        LogKernelOp::apply_log(&fk, &log_v, &mut log_out);
        for i in 0..25 {
            // apply() returns the *represented* kernel (scaled by
            // exp(-log_scale)); the log view is the true kernel.
            let want = log_out[i].exp() * (-fk.log_scale()).exp();
            let rel = ((plain[i] as f64) - want).abs() / want.abs().max(1e-30);
            assert!(rel < 1e-4, "row {i}: plain {} vs exp(log) {}", plain[i], want);
        }
    }

    #[test]
    fn batched_log_applies_match_vector_applies_bitwise() {
        // Fused factored + fused dense + the default per-pair loop (via
        // the borrowed-cost adapter) all reproduce the vector log applies
        // exactly, pair by pair.
        let mut rng = Rng::seed_from(7);
        let (mu, nu) = data::gaussian_blobs(18, &mut rng);
        let eps = 1e-2;
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, 16, &mut rng);
        let fk = FactoredKernel::from_measures_stabilized(&map, &mu, &nu);
        let dk = DenseKernel::from_measures(&mu, &nu, eps);
        let adapter = CostMatrixLogKernel::new(dk.cost(), eps);
        let b = 3;
        let ts: Vec<Vec<f64>> =
            (0..b).map(|p| (0..18).map(|j| (p * 11 + j) as f64 * 0.5 - 10.0).collect()).collect();
        for kernel in [&fk as &dyn LogKernelOp, &dk as &dyn LogKernelOp, &adapter] {
            let (n, m) = kernel.shape();
            let mut outs: Vec<Vec<f64>> = (0..b).map(|_| vec![0.0f64; n]).collect();
            kernel.apply_log_batch(&ts, &mut outs);
            let mut outs_t: Vec<Vec<f64>> = (0..b).map(|_| vec![0.0f64; m]).collect();
            kernel.apply_log_batch_t(&ts, &mut outs_t);
            for p in 0..b {
                let mut want = vec![0.0f64; n];
                kernel.apply_log(&ts[p], &mut want);
                let mut want_t = vec![0.0f64; m];
                kernel.apply_log_t(&ts[p], &mut want_t);
                for (got, want) in outs[p].iter().zip(&want) {
                    assert_eq!(got.to_bits(), want.to_bits(), "{} pair {p}", kernel.describe());
                }
                for (got, want) in outs_t[p].iter().zip(&want_t) {
                    assert_eq!(got.to_bits(), want.to_bits(), "{} pair {p} ^T", kernel.describe());
                }
            }
        }
    }

    #[test]
    fn cost_matrix_adapter_matches_dense_kernel_view() {
        let mut rng = Rng::seed_from(3);
        let (mu, nu) = data::gaussian_blobs(12, &mut rng);
        let eps = 0.05;
        let dk = DenseKernel::from_measures(&mu, &nu, eps);
        let adapter = CostMatrixLogKernel::new(dk.cost(), eps);
        let t: Vec<f64> = (0..12).map(|j| -(j as f64)).collect();
        let (mut a, mut b) = (vec![0.0f64; 12], vec![0.0f64; 12]);
        LogKernelOp::apply_log(&dk, &t, &mut a);
        adapter.apply_log(&t, &mut b);
        assert_eq!(a, b, "adapter and DenseKernel stream the same cost");
        assert_eq!(adapter.shape(), (12, 12));
        assert!(adapter.describe().contains("cost-matrix"));
    }

    #[test]
    fn from_matrix_log_view_round_trips() {
        // DenseKernel::from_matrix reconstructs cost = -eps log k; its
        // log view must reproduce log k.
        let k = Mat::from_rows(&[vec![0.5, 0.1], vec![0.25, 1.0]]);
        let dk = DenseKernel::from_matrix(k.clone(), 0.7);
        let t = vec![f64::NEG_INFINITY, 0.0];
        let mut out = vec![0.0f64; 2];
        LogKernelOp::apply_log(&dk, &t, &mut out);
        // With t = (-inf, 0), out_i = log k[i][1].
        assert!((out[0] - (0.1f64).ln()).abs() < 1e-6);
        assert!((out[1] - (1.0f64).ln()).abs() < 1e-6);
    }
}
