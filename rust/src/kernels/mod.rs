//! Kernel operators — the object Sinkhorn iterates against.
//!
//! [`KernelOp`] abstracts "apply K (or K^T) to a vector". All three of the
//! paper's contenders implement it, so the *same* Sinkhorn code measures
//! their per-iteration complexity honestly:
//!
//! * [`DenseKernel`] — the `Sin` baseline: explicit `exp(-C/eps)`,
//!   O(nm) per apply.
//! * [`FactoredKernel`] — the paper's `RF` method: `K = Phi_x Phi_y^T`,
//!   O(r(n+m)) per apply, positive by construction.
//! * [`NystromKernel`] — the `Nys` arm (Altschuler et al. '18, adaptive
//!   sampling per arXiv:1812.05189): data-adaptive low rank, O(r(n+m))
//!   per apply but **not** positivity-safe;
//!   [`NystromKernel::validate_positive`] surfaces the failure mode the
//!   paper contrasts against, and its clamped signed log view is gated
//!   off whenever clamping would distort the apply (see [`nystrom`]).
//!
//! Kernels that can also stream *log-space* applies — the row/column
//! logsumexp of `log K + input` that log-domain Sinkhorn iterates —
//! additionally implement [`LogKernelOp`] (see [`logspace`]) and expose
//! it through [`KernelOp::as_log_kernel`], which is how the solvers
//! escalate to the stabilised path at small eps without knowing the
//! concrete kernel type.

use crate::data::Measure;
use crate::features::{self, FeatureMap};
use crate::linalg::{self, Mat};
use crate::runtime::pool::Pool;

pub mod logspace;
pub mod nystrom;

pub use logspace::{CostMatrixLogKernel, LogKernelOp};
pub use nystrom::NystromKernel;

/// Matrix-free kernel operator.
pub trait KernelOp {
    /// Rows of K (size of the first measure).
    fn rows(&self) -> usize;

    /// Columns of K (size of the second measure).
    fn cols(&self) -> usize;

    /// `out = K v` (length rows).
    fn apply_into(&self, v: &[f32], out: &mut [f32]);

    /// `out = K^T u` (length cols).
    fn apply_t_into(&self, u: &[f32], out: &mut [f32]);

    /// `K v`, allocating.
    fn apply(&self, v: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.rows()];
        self.apply_into(v, &mut out);
        out
    }

    /// `K^T u`, allocating.
    fn apply_t(&self, u: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; self.cols()];
        self.apply_t_into(u, &mut out);
        out
    }

    /// Column-blocked apply: `out.row(k) = K @ vs.row(k)` for every pair
    /// row (`vs`: B×cols, `out`: B×rows, both pair-major). The default
    /// loops the vector apply per pair — trivially identical to B
    /// sequential applies; kernels with a fused mat-mat path (the
    /// factored kernel) override it with one that is **bitwise identical
    /// per pair** to the vector apply, which is the contract the batched
    /// Sinkhorn engine ([`crate::sinkhorn::solve_batch`]) relies on.
    fn apply_batch_into(&self, vs: &crate::linalg::Mat, out: &mut crate::linalg::Mat) {
        assert_eq!(vs.cols(), self.cols(), "apply_batch: input length");
        assert_eq!(out.shape(), (vs.rows(), self.rows()), "apply_batch: output shape");
        for k in 0..vs.rows() {
            self.apply_into(vs.row(k), out.row_mut(k));
        }
    }

    /// Column-blocked transposed apply: `out.row(k) = K^T @ us.row(k)`
    /// (`us`: B×rows, `out`: B×cols). Same contract as
    /// [`KernelOp::apply_batch_into`].
    fn apply_batch_t_into(&self, us: &crate::linalg::Mat, out: &mut crate::linalg::Mat) {
        assert_eq!(us.cols(), self.rows(), "apply_batch_t: input length");
        assert_eq!(out.shape(), (us.rows(), self.cols()), "apply_batch_t: output shape");
        for k in 0..us.rows() {
            self.apply_t_into(us.row(k), out.row_mut(k));
        }
    }

    /// Smallest kernel entry (drives Sinkhorn's iteration bound via
    /// `Q_theta = -log min K_ij`, Thm 3.1). May be an estimate.
    fn min_entry(&self) -> f64;

    /// Log of the scalar relating the *represented* kernel to the true
    /// one: `K_true = exp(log_scale) * K_repr`. Stabilised factored
    /// kernels renormalise their factors to dodge f32 underflow at small
    /// eps and report the compensation here; the Sinkhorn objective is
    /// corrected by `-eps * log_scale` (scaling K by c shifts the dual
    /// estimate by -eps log c; the plan is unchanged).
    fn log_scale(&self) -> f64 {
        0.0
    }

    /// Floating-point operations per `apply` — used by benches to report
    /// algorithmic complexity alongside wall-clock.
    fn flops_per_apply(&self) -> u64;

    /// Human-readable label for reports.
    fn label(&self) -> String;

    /// The log-domain view of this kernel, when it supports matrix-free
    /// log-space applies ([`LogKernelOp`]). Solvers use this to escalate
    /// to the stabilised log-domain iteration when plain Alg. 1 produces
    /// non-finite scalings at small eps. Defaults to `None`; kernels may
    /// also gate the view at runtime (Nyström exposes its clamped signed
    /// log view only where it agrees with the plain apply — see
    /// [`nystrom`]).
    fn as_log_kernel(&self) -> Option<&dyn LogKernelOp> {
        None
    }
}

/// Explicit dense Gibbs kernel `K_ij = exp(-||x_i - y_j||^2 / eps)`.
///
/// The kernel keeps the *cost matrix* it was exponentiated from: `k` is
/// floored at `exp(LOG_FLOOR)` for f32 positivity, but the log-domain
/// path ([`LogKernelOp`]) reads `-cost/eps` unclamped, which is what
/// makes the dense baseline exact at regularisations where `k` itself
/// has flushed to the floor.
pub struct DenseKernel {
    /// The materialised kernel matrix (n, m).
    pub k: Mat,
    pub eps: f64,
    /// The cost matrix C with `K = exp(-C/eps)` before flooring.
    cost: Mat,
}

impl DenseKernel {
    /// Build from two point clouds with the squared Euclidean cost.
    pub fn from_measures(mu: &Measure, nu: &Measure, eps: f64) -> Self {
        assert_eq!(mu.dim(), nu.dim());
        let (n, m) = (mu.len(), nu.len());
        let mut cost = Mat::zeros(n, m);
        for i in 0..n {
            let xi = mu.points.row(i);
            let row = cost.row_mut(i);
            for (j, cell) in row.iter_mut().enumerate() {
                let yj = nu.points.row(j);
                let d2: f64 = xi
                    .iter()
                    .zip(yj)
                    .map(|(&a, &b)| ((a - b) as f64) * ((a - b) as f64))
                    .sum();
                *cell = d2 as f32;
            }
        }
        Self::from_cost_owned(cost, eps)
    }

    /// Build from an arbitrary cost matrix.
    pub fn from_cost(cost: &Mat, eps: f64) -> Self {
        Self::from_cost_owned(cost.clone(), eps)
    }

    fn from_cost_owned(cost: Mat, eps: f64) -> Self {
        // Same underflow floor as the feature maps: keeps rows of K
        // strictly positive in f32 so tiny-eps runs fail loudly in the
        // *marginals*, not silently via 0-division. The unfloored cost is
        // retained for the log-domain path.
        let k = cost
            .map(|c| ((-c as f64 / eps).max(crate::features::LOG_FLOOR as f64)).exp() as f32);
        DenseKernel { k, eps, cost }
    }

    /// Build from an explicit kernel matrix (all entries must be
    /// positive); the cost is reconstructed as `-eps log k`, so the
    /// log-domain view agrees with the given matrix exactly (up to f32
    /// rounding of the logs).
    pub fn from_matrix(k: Mat, eps: f64) -> Self {
        let cost = k.map(|v| (-eps * (v as f64).ln()) as f32);
        DenseKernel { k, eps, cost }
    }

    /// The retained cost matrix (`K = exp(-cost/eps)` before flooring).
    pub fn cost(&self) -> &Mat {
        &self.cost
    }
}

impl KernelOp for DenseKernel {
    fn rows(&self) -> usize {
        self.k.rows()
    }

    fn cols(&self) -> usize {
        self.k.cols()
    }

    fn apply_into(&self, v: &[f32], out: &mut [f32]) {
        linalg::matvec_into(&self.k, v, out);
    }

    fn apply_t_into(&self, u: &[f32], out: &mut [f32]) {
        linalg::matvec_t_into(&self.k, u, out);
    }

    fn apply_batch_into(&self, vs: &Mat, out: &mut Mat) {
        // One stream over the materialised kernel serves all B pairs;
        // bitwise identical per pair to `apply_into` (shared row kernel).
        linalg::matmat_into(&self.k, vs, out);
    }

    fn apply_batch_t_into(&self, us: &Mat, out: &mut Mat) {
        linalg::matmat_t_into(&self.k, us, out);
    }

    fn min_entry(&self) -> f64 {
        self.k.min_entry() as f64
    }

    fn flops_per_apply(&self) -> u64 {
        2 * (self.rows() as u64) * (self.cols() as u64)
    }

    fn label(&self) -> String {
        format!("Sin(dense {}x{})", self.rows(), self.cols())
    }

    fn as_log_kernel(&self) -> Option<&dyn LogKernelOp> {
        Some(self)
    }
}

/// The paper's factored kernel `K = Phi_x Phi_y^T` with positive factors.
///
/// The kernel is `Sync` (scratch lives behind a `Mutex`), so the three
/// transport problems of a Sinkhorn divergence can be solved concurrently
/// on three kernels, and applies may additionally row-chunk their matvecs
/// over an embedded [`Pool`] (see [`FactoredKernel::with_pool`]).
pub struct FactoredKernel {
    /// (n, r) strictly positive.
    pub phi_x: Mat,
    /// (m, r) strictly positive.
    pub phi_y: Mat,
    /// Raw log factors: `log K_true = logsumexp_k(log_phi_x + log_phi_y)`
    /// exactly, with no shift and no f32 underflow floor. The log-domain
    /// applies ([`LogKernelOp`]) stream these at O(r(n+m)) per apply.
    /// Pre-populated by [`FactoredKernel::from_log_factors`] (which holds
    /// the raw logs anyway); computed lazily as elementwise `ln` on first
    /// log-domain use otherwise, so plain-path constructions (e.g. the
    /// GAN's per-step kernels) pay nothing for the capability.
    log_factors: std::sync::OnceLock<(Mat, Mat)>,
    /// `K_true = exp(log_scale) * phi_x phi_y^T` (0 for unscaled factors).
    log_scale: f64,
    /// Scratch for the r-vector between the two matvecs.
    scratch: std::sync::Mutex<Vec<f32>>,
    /// Intra-apply parallelism policy (serial by default).
    pool: Pool,
}

impl FactoredKernel {
    /// Build by evaluating a positive feature map on both clouds.
    pub fn from_measures<F: FeatureMap>(map: &F, mu: &Measure, nu: &Measure) -> Self {
        Self::from_factors(map.feature_matrix(&mu.points), map.feature_matrix(&nu.points))
    }

    /// [`FactoredKernel::from_measures`] with the feature evaluation
    /// parallelised over `pool`; the kernel keeps the pool for its own
    /// applies. Bitwise-identical factors to the serial path.
    pub fn from_measures_pooled<F: FeatureMap + Sync>(
        map: &F,
        mu: &Measure,
        nu: &Measure,
        pool: Pool,
    ) -> Self {
        Self::from_factors(
            features::par_feature_matrix(map, &mu.points, &pool),
            features::par_feature_matrix(map, &nu.points, &pool),
        )
        .with_pool(pool)
    }

    /// Build with f32 underflow stabilisation: log-features are shifted so
    /// each factor's largest entry is 1 before exponentiating, and the
    /// shift is carried in `log_scale`. This is what lets the RF method
    /// run at regularisations where the raw Gibbs values live around
    /// exp(-400) — far outside f32 — matching the paper's f64 experiments.
    pub fn from_measures_stabilized<F: FeatureMap>(map: &F, mu: &Measure, nu: &Measure) -> Self {
        let lx = map.log_feature_matrix(&mu.points);
        let ly = map.log_feature_matrix(&nu.points);
        Self::from_log_factors(lx, ly)
    }

    /// [`FactoredKernel::from_measures_stabilized`] with the log-feature
    /// evaluation parallelised over `pool`; the kernel keeps the pool for
    /// its own applies.
    pub fn from_measures_stabilized_pooled<F: FeatureMap + Sync>(
        map: &F,
        mu: &Measure,
        nu: &Measure,
        pool: Pool,
    ) -> Self {
        let lx = features::par_log_feature_matrix(map, &mu.points, &pool);
        let ly = features::par_log_feature_matrix(map, &nu.points, &pool);
        Self::from_log_factors(lx, ly).with_pool(pool)
    }

    /// Build from log-feature matrices, normalising each by its max.
    ///
    /// The raw log factors are retained for the [`LogKernelOp`] path, so
    /// the log-domain view of this kernel is exact even where the
    /// exponentiated f32 factors hit the `LOG_FLOOR` clamp.
    pub fn from_log_factors(lx: Mat, ly: Mat) -> Self {
        assert_eq!(lx.cols(), ly.cols(), "factor rank mismatch");
        let sx = lx.max_entry() as f64;
        let sy = ly.max_entry() as f64;
        let clamp_exp = |shift: f64| {
            move |v: f32| {
                (v - shift as f32)
                    .clamp(crate::features::LOG_FLOOR, crate::features::LOG_CEIL)
                    .exp()
            }
        };
        let phi_x = lx.map(clamp_exp(sx));
        let phi_y = ly.map(clamp_exp(sy));
        let r = lx.cols();
        let log_factors = std::sync::OnceLock::new();
        log_factors.set((lx, ly)).ok();
        FactoredKernel {
            phi_x,
            phi_y,
            log_factors,
            log_scale: sx + sy,
            scratch: std::sync::Mutex::new(vec![0.0; r]),
            pool: Pool::serial(),
        }
    }

    /// Build from explicit factor matrices (e.g. computed by the AOT'd
    /// Pallas kernel through the PJRT runtime). The log factors for the
    /// [`LogKernelOp`] path are the elementwise logs (`-inf` for exact
    /// zeros, which logsumexp treats as absent terms), computed on first
    /// log-domain use.
    ///
    /// The log view is exact **for the factors as given**: if they came
    /// from a clamp-floored feature evaluation (`eval_into` floors at
    /// `exp(LOG_FLOOR)`), the floor is part of the kernel this operator
    /// represents — in plain and log domain alike. For small-eps
    /// fidelity to the unclamped kernel, build from raw log features
    /// instead ([`FactoredKernel::from_measures_stabilized`] /
    /// [`FactoredKernel::from_log_factors`], whose retained raw logs
    /// bypass the floor entirely); see EXPERIMENTS.md §Stabilisation.
    pub fn from_factors(phi_x: Mat, phi_y: Mat) -> Self {
        assert_eq!(phi_x.cols(), phi_y.cols(), "factor rank mismatch");
        let r = phi_x.cols();
        FactoredKernel {
            phi_x,
            phi_y,
            log_factors: std::sync::OnceLock::new(),
            log_scale: 0.0,
            scratch: std::sync::Mutex::new(vec![0.0; r]),
            pool: Pool::serial(),
        }
    }

    /// The raw log factors backing the [`LogKernelOp`] view (lazily
    /// `ln(phi)` when the kernel was built from exponentiated factors).
    fn log_factors(&self) -> &(Mat, Mat) {
        self.log_factors
            .get_or_init(|| (self.phi_x.map(f32::ln), self.phi_y.map(f32::ln)))
    }

    /// Set the intra-apply parallelism policy. The pooled matvec kernels
    /// are deterministic in the thread count, so this changes wall-clock
    /// only, never the numbers (rust/tests/parallel_equivalence.rs).
    pub fn with_pool(mut self, pool: Pool) -> Self {
        self.pool = pool;
        self
    }

    /// The kernel's parallelism policy (cloning shares the same workers).
    pub fn pool(&self) -> Pool {
        self.pool.clone()
    }

    /// Feature count r.
    pub fn rank(&self) -> usize {
        self.phi_x.cols()
    }

    /// Materialise K (tests / small problems only: O(nmr)).
    pub fn to_dense(&self) -> Mat {
        linalg::matmul(&self.phi_x, &self.phi_y.transpose())
    }
}

impl KernelOp for FactoredKernel {
    fn rows(&self) -> usize {
        self.phi_x.rows()
    }

    fn cols(&self) -> usize {
        self.phi_y.rows()
    }

    fn apply_into(&self, v: &[f32], out: &mut [f32]) {
        // K v = Phi_x (Phi_y^T v): two skinny matvecs, O(r(n+m)).
        let mut t = self.scratch.lock().unwrap();
        linalg::matvec_t_into_pooled(&self.phi_y, v, &mut t, &self.pool);
        linalg::matvec_into_pooled(&self.phi_x, &t, out, &self.pool);
    }

    fn apply_t_into(&self, u: &[f32], out: &mut [f32]) {
        let mut t = self.scratch.lock().unwrap();
        linalg::matvec_t_into_pooled(&self.phi_x, u, &mut t, &self.pool);
        linalg::matvec_into_pooled(&self.phi_y, &t, out, &self.pool);
    }

    /// Fused multi-pair apply: `K V = Phi_x (Phi_y^T V)` as two skinny
    /// mat-mats, O(r(n+m)) per pair with **one** stream over each factor
    /// for all B pairs instead of B. Each pair row of the result is
    /// bitwise identical to `apply_into` on that pair's vector, at every
    /// pool size — the column-blocked kernels share `row_dot`/`saxpy_rows`
    /// and the fixed chunk grids with the vector kernels
    /// (`rust/tests/batched_equivalence.rs`). The O(B·r) intermediate is
    /// allocated per call (a few KB; the Mutex'd vector scratch stays
    /// dedicated to the vector path).
    fn apply_batch_into(&self, vs: &Mat, out: &mut Mat) {
        let mut mid = Mat::zeros(vs.rows(), self.rank());
        linalg::matmat_t_into_pooled(&self.phi_y, vs, &mut mid, &self.pool);
        linalg::matmat_into_pooled(&self.phi_x, &mid, out, &self.pool);
    }

    fn apply_batch_t_into(&self, us: &Mat, out: &mut Mat) {
        let mut mid = Mat::zeros(us.rows(), self.rank());
        linalg::matmat_t_into_pooled(&self.phi_x, us, &mut mid, &self.pool);
        linalg::matmat_into_pooled(&self.phi_y, &mid, out, &self.pool);
    }

    fn min_entry(&self) -> f64 {
        // Cheap positive lower bound without materialising K:
        // min_ij sum_k phi_x[i,k] phi_y[j,k] >= sum_k (min_i phi_x[.,k]) (min_j phi_y[.,k]).
        let r = self.rank();
        let mut min_x = vec![f32::INFINITY; r];
        let mut min_y = vec![f32::INFINITY; r];
        for i in 0..self.phi_x.rows() {
            for (k, &v) in self.phi_x.row(i).iter().enumerate() {
                min_x[k] = min_x[k].min(v);
            }
        }
        for j in 0..self.phi_y.rows() {
            for (k, &v) in self.phi_y.row(j).iter().enumerate() {
                min_y[k] = min_y[k].min(v);
            }
        }
        min_x.iter().zip(&min_y).map(|(&a, &b)| (a as f64) * (b as f64)).sum()
    }

    fn flops_per_apply(&self) -> u64 {
        2 * (self.rank() as u64) * ((self.rows() + self.cols()) as u64)
    }

    fn log_scale(&self) -> f64 {
        self.log_scale
    }

    fn label(&self) -> String {
        format!("RF(r={} {}x{})", self.rank(), self.rows(), self.cols())
    }

    fn as_log_kernel(&self) -> Option<&dyn LogKernelOp> {
        Some(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data;
    use crate::features::GaussianFeatureMap;
    use crate::rng::Rng;

    fn clouds(seed: u64, n: usize) -> (Measure, Measure) {
        let mut rng = Rng::seed_from(seed);
        data::gaussian_blobs(n, &mut rng)
    }

    #[test]
    fn dense_kernel_entries_are_gibbs() {
        let (mu, nu) = clouds(0, 10);
        let k = DenseKernel::from_measures(&mu, &nu, 0.5);
        let d2: f64 = mu
            .points
            .row(3)
            .iter()
            .zip(nu.points.row(7))
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        assert!(((k.k[(3, 7)] as f64) - (-d2 / 0.5).exp()).abs() < 1e-6);
        assert!(k.min_entry() > 0.0);
    }

    #[test]
    fn factored_apply_equals_dense_apply() {
        let (mu, nu) = clouds(1, 30);
        let mut rng = Rng::seed_from(2);
        let fm = GaussianFeatureMap::fit(&mu, &nu, 0.5, 16, &mut rng);
        let fk = FactoredKernel::from_measures(&fm, &mu, &nu);
        let dense = fk.to_dense();
        let v: Vec<f32> = (0..nu.len()).map(|i| 0.1 + (i as f32) * 0.01).collect();
        let got = fk.apply(&v);
        let want = linalg::matvec(&dense, &v);
        assert!(linalg::max_abs_diff(&got, &want) < 1e-5);
        let u: Vec<f32> = (0..mu.len()).map(|i| 0.2 + (i as f32) * 0.01).collect();
        let got_t = fk.apply_t(&u);
        let want_t = linalg::matvec_t(&dense, &u);
        assert!(linalg::max_abs_diff(&got_t, &want_t) < 1e-5);
    }

    #[test]
    fn batched_applies_match_vector_applies_bitwise() {
        // The fused factored path and the default per-pair loop must both
        // reproduce the vector applies exactly, pair by pair.
        let (mu, nu) = clouds(17, 40);
        let mut rng = Rng::seed_from(18);
        let fm = GaussianFeatureMap::fit(&mu, &nu, 0.5, 24, &mut rng);
        let fk = FactoredKernel::from_measures(&fm, &mu, &nu);
        let dk = DenseKernel::from_measures(&mu, &nu, 0.5);
        let b = 3;
        let vs = Mat::from_fn(b, nu.len(), |p, j| 0.1 + 0.01 * (p * 7 + j) as f32);
        let us = Mat::from_fn(b, mu.len(), |p, i| 0.2 + 0.01 * (p * 5 + i) as f32);
        for kernel in [&fk as &dyn KernelOp, &dk as &dyn KernelOp] {
            let mut out = Mat::zeros(b, kernel.rows());
            kernel.apply_batch_into(&vs, &mut out);
            let mut out_t = Mat::zeros(b, kernel.cols());
            kernel.apply_batch_t_into(&us, &mut out_t);
            for p in 0..b {
                let want = kernel.apply(vs.row(p));
                let want_t = kernel.apply_t(us.row(p));
                for (got, want) in out.row(p).iter().zip(&want) {
                    assert_eq!(got.to_bits(), want.to_bits(), "{} pair {p}", kernel.label());
                }
                for (got, want) in out_t.row(p).iter().zip(&want_t) {
                    assert_eq!(got.to_bits(), want.to_bits(), "{} pair {p} ^T", kernel.label());
                }
            }
        }
    }

    #[test]
    fn factored_positivity_preserved_for_positive_input() {
        let (mu, nu) = clouds(3, 40);
        let mut rng = Rng::seed_from(4);
        let fm = GaussianFeatureMap::fit(&mu, &nu, 0.1, 8, &mut rng);
        let fk = FactoredKernel::from_measures(&fm, &mu, &nu);
        let v = vec![1.0; nu.len()];
        assert!(fk.apply(&v).iter().all(|&x| x > 0.0), "positive in, positive out — any r");
    }

    #[test]
    fn factored_min_entry_is_lower_bound() {
        let (mu, nu) = clouds(5, 15);
        let mut rng = Rng::seed_from(6);
        let fm = GaussianFeatureMap::fit(&mu, &nu, 0.5, 8, &mut rng);
        let fk = FactoredKernel::from_measures(&fm, &mu, &nu);
        let bound = fk.min_entry();
        let actual = fk.to_dense().min_entry() as f64;
        assert!(bound > 0.0);
        assert!(bound <= actual * (1.0 + 1e-5), "bound {bound} actual {actual}");
    }

    #[test]
    fn flops_reflect_complexity() {
        let (mu, nu) = clouds(7, 100);
        let mut rng = Rng::seed_from(8);
        let fm = GaussianFeatureMap::fit(&mu, &nu, 0.5, 10, &mut rng);
        let fk = FactoredKernel::from_measures(&fm, &mu, &nu);
        let dk = DenseKernel::from_measures(&mu, &nu, 0.5);
        assert_eq!(dk.flops_per_apply(), 2 * 100 * 100);
        assert_eq!(fk.flops_per_apply(), 2 * 10 * 200);
        assert!(fk.flops_per_apply() < dk.flops_per_apply());
    }

    #[test]
    fn kernel_labels() {
        let (mu, nu) = clouds(15, 5);
        let dk = DenseKernel::from_measures(&mu, &nu, 1.0);
        assert!(dk.label().starts_with("Sin"));
    }
}
