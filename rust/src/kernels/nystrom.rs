//! Nyström low-rank approximation of the Gibbs kernel — the `Nys` arm.
//!
//! The planner's third backend ([`crate::api::Backend::Nystrom`]): pick
//! `rank` landmark points `L` from the union of the two clouds, form
//!
//! ```text
//! K  ≈  A W⁺ B,    A = K(x, L),  W = K(L, L),  B = K(L, y)
//! ```
//!
//! and apply in O(rank·(n+m)) like the factored kernel. Two landmark
//! selection schemes, both driven by a seeded [`Rng`] so a plan replays
//! bit-identically on every host and shard (the seed rides the plan
//! through [`crate::api::TaskEnvelope`]; workers rebuild the same
//! landmarks):
//!
//! * **uniform** ([`NystromKernel::from_measures`]) — `rank` indices
//!   sampled uniformly without replacement from the union cloud; the
//!   classical baseline.
//! * **adaptive** ([`NystromKernel::from_measures_adaptive`]) — greedy
//!   farthest-point (k-center) sampling, the geometric variant of the
//!   recursive leverage-score sampling of Altschuler–Bach–Rudi–
//!   Niles-Weed (arXiv:1812.05189): after a seeded uniform first pick,
//!   each landmark maximises the squared distance to the chosen set
//!   (ties resolve to the lowest index, so the sequence is a pure
//!   function of the seed). For the Gibbs kernel, well-spread landmarks
//!   approximate the leverage-score distribution without the O(n r²)
//!   score recursion.
//!
//! Factor construction routes the O((n+m)·rank·dim) inner-product work
//! through the pooled/SIMD [`crate::linalg`] mat-mat kernels
//! (`d²(p, l) = |p|² + |l|² − 2⟨p, l⟩` with the cross terms as one
//! column-blocked product per factor), not scalar per-entry loops.
//!
//! ## The clamped log view
//!
//! Unlike the paper's positive features, `A W⁺ B` is **not** positivity
//! safe: `W⁺` is signed, so the approximation can produce negative
//! entries — the failure mode the paper contrasts against
//! ([`NystromKernel::validate_positive`]). The kernel still exposes a
//! [`LogKernelOp`] view so log-domain escalation and eps-annealing work
//! on this arm where the approximation is sound: the composed factor
//! `P = A·W⁺` (n×rank) is split into its positive and negative parts,
//! entries are clamped at the documented positive floor
//! `exp(`[`LOG_FLOOR`]`)` (smaller-magnitude entries behave as absent
//! logsumexp terms), and a log apply runs the two positive-factor chains
//! `P⁺·(B eᵗ)` and `P⁻·(B eᵗ)` as nested logsumexps, combining them by
//! signed subtraction in f64. Where a signed combination is non-positive
//! the result is `-inf`/NaN and the solvers surface a typed
//! [`Error::SinkhornDiverged`] instead of garbage. The view is gated:
//! [`KernelOp::as_log_kernel`] returns `None` (and
//! [`NystromKernel::validate_positive`] escalates to
//! [`Error::NotPositive`]) whenever the clamped view disagrees with the
//! plain apply on a ones probe by more than [`LOG_VIEW_TOL`] — i.e.
//! whenever clamping would distort the apply.

use crate::data::Measure;
use crate::error::{Error, Result};
use crate::features::LOG_FLOOR;
use crate::linalg::{self, Mat};
use crate::rng::Rng;
use crate::runtime::pool::Pool;

use super::logspace::LogKernelOp;
use super::KernelOp;

/// Relative ones-probe agreement required between the plain apply and
/// the clamped log view before the log view is exposed through
/// [`KernelOp::as_log_kernel`]. Beyond this, clamping (or a loss of
/// positivity) has materially distorted the operator and the log-domain
/// solvers would converge to the wrong kernel.
pub const LOG_VIEW_TOL: f64 = 0.05;

/// The clamped signed log factors backing the [`LogKernelOp`] view.
struct LogView {
    /// (n, rank): `ln(max(P, 0))` for `P = A·W⁺`, floored at [`LOG_FLOOR`].
    lpp: Mat,
    /// (n, rank): `ln(max(-P, 0))`, floored at [`LOG_FLOOR`].
    lpn: Mat,
    /// (m, rank): `ln(Bᵀ)`, floored at [`LOG_FLOOR`] (B ≥ 0 by construction).
    lbt: Mat,
    /// Smallest composed-factor entry before clamping (diagnostic for
    /// [`Error::NotPositive`]; ≤ 0 whenever the split is non-trivial).
    composed_min: f64,
}

/// Nyström kernel `A W⁺ B` over seeded landmarks. `Sync` (scratch lives
/// behind a `Mutex`, like [`super::FactoredKernel`]), so the three
/// transport problems of a divergence solve concurrently; applies
/// row-chunk over an embedded [`Pool`] ([`NystromKernel::with_pool`]).
pub struct NystromKernel {
    /// (n, rank) = K(x, landmarks).
    a: Mat,
    /// (rank, rank) ridge pseudo-inverse of the landmark block.
    w_pinv: Mat,
    /// (rank, m) = K(landmarks, y).
    b: Mat,
    pub eps: f64,
    /// Landmark selection scheme used (for labels and plan explain).
    adaptive: bool,
    /// Landmark indices into the union cloud (`< n` → `mu`, else `nu`).
    landmarks: Vec<usize>,
    /// Scratch for the two rank-vectors between the three matvecs.
    scratch: std::sync::Mutex<(Vec<f32>, Vec<f32>)>,
    /// Intra-apply parallelism policy (serial by default).
    pool: Pool,
    /// Lazily-composed clamped log factors (first log-domain use).
    log_view: std::sync::OnceLock<LogView>,
    /// Lazily-evaluated ones-probe gate for the log view.
    log_view_ok: std::sync::OnceLock<bool>,
}

impl NystromKernel {
    /// Build with `rank` uniformly-sampled landmarks and a small ridge.
    ///
    /// Landmarks come from both clouds (union sampling keeps the column
    /// space relevant for the `K_xy` rectangle). Deterministic in `rng`:
    /// the same seed rebuilds the same kernel on any host.
    pub fn from_measures(
        mu: &Measure,
        nu: &Measure,
        eps: f64,
        rank: usize,
        rng: &mut Rng,
    ) -> Self {
        assert!((1..=nu.len()).contains(&rank));
        let idx = rng.sample_indices(mu.len() + nu.len(), rank);
        Self::build(mu, nu, eps, idx, false, Pool::serial())
    }

    /// Build with `rank` adaptively-selected landmarks: greedy
    /// farthest-point sampling over the union cloud (see module docs),
    /// seeded by `rng` (one uniform draw for the first landmark; the
    /// rest of the sequence is deterministic given that pick).
    pub fn from_measures_adaptive(
        mu: &Measure,
        nu: &Measure,
        eps: f64,
        rank: usize,
        rng: &mut Rng,
    ) -> Self {
        assert!((1..=nu.len()).contains(&rank));
        let pool = Pool::serial();
        let union = union_matrix(mu, nu);
        let norms = row_sq_norms(&union);
        let idx = farthest_point_landmarks(&union, &norms, rank, rng, &pool);
        Self::build(mu, nu, eps, idx, true, pool)
    }

    /// The landmark **selection** of [`NystromKernel::from_measures`],
    /// without the factor construction: `rank` indices into the union
    /// cloud, sampled uniformly without replacement. Split out so the
    /// coordinator's landmark cache can amortise the selection across
    /// hot groups and rebuild via [`NystromKernel::from_landmarks`].
    pub fn select_landmarks_uniform(
        mu: &Measure,
        nu: &Measure,
        rank: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        assert!((1..=nu.len()).contains(&rank));
        rng.sample_indices(mu.len() + nu.len(), rank)
    }

    /// The landmark **selection** of
    /// [`NystromKernel::from_measures_adaptive`], without the factor
    /// construction: the seeded greedy farthest-point sequence over the
    /// union cloud — the O(r·(n+m)·d) setup cost the landmark cache
    /// amortises.
    pub fn select_landmarks_adaptive(
        mu: &Measure,
        nu: &Measure,
        rank: usize,
        rng: &mut Rng,
    ) -> Vec<usize> {
        assert!((1..=nu.len()).contains(&rank));
        let pool = Pool::serial();
        let union = union_matrix(mu, nu);
        let norms = row_sq_norms(&union);
        farthest_point_landmarks(&union, &norms, rank, rng, &pool)
    }

    /// Build from pre-selected landmark indices (what
    /// [`NystromKernel::select_landmarks_uniform`] /
    /// [`NystromKernel::select_landmarks_adaptive`] return — e.g. out of
    /// the coordinator's landmark cache). Bit-identical to the
    /// corresponding `from_measures*` constructor for the same indices:
    /// the factor construction is a pure function of `(mu, nu, eps, idx)`.
    pub fn from_landmarks(
        mu: &Measure,
        nu: &Measure,
        eps: f64,
        idx: Vec<usize>,
        adaptive: bool,
    ) -> Self {
        assert!(!idx.is_empty());
        assert!(idx.iter().all(|&t| t < mu.len() + nu.len()), "landmark index out of bounds");
        Self::build(mu, nu, eps, idx, adaptive, Pool::serial())
    }

    /// Shared factor construction from chosen landmark indices. The
    /// cross inner products run through the pooled column-blocked
    /// mat-mat kernels; only the final `exp` is per-entry.
    fn build(
        mu: &Measure,
        nu: &Measure,
        eps: f64,
        idx: Vec<usize>,
        adaptive: bool,
        pool: Pool,
    ) -> Self {
        assert_eq!(mu.dim(), nu.dim());
        let rank = idx.len();
        let d = mu.dim();
        let lmk = Mat::from_fn(rank, d, |k, j| {
            let t = idx[k];
            if t < mu.len() { mu.points.row(t)[j] } else { nu.points.row(t - mu.len())[j] }
        });
        let lnorms = row_sq_norms(&lmk);
        let xnorms = row_sq_norms(&mu.points);
        let ynorms = row_sq_norms(&nu.points);
        let a = gibbs_block(&mu.points, &xnorms, &lmk, &lnorms, eps, &pool);
        let b = gibbs_block(&nu.points, &ynorms, &lmk, &lnorms, eps, &pool).transpose();
        let w = gibbs_block(&lmk, &lnorms, &lmk, &lnorms, eps, &pool);
        let w_pinv = ridge_inverse(&w, 1e-3);
        NystromKernel {
            a,
            w_pinv,
            b,
            eps,
            adaptive,
            landmarks: idx,
            scratch: std::sync::Mutex::new((vec![0.0; rank], vec![0.0; rank])),
            pool,
            log_view: std::sync::OnceLock::new(),
            log_view_ok: std::sync::OnceLock::new(),
        }
    }

    /// Set the intra-apply parallelism policy. The pooled kernels are
    /// deterministic in the thread count, so this changes wall-clock
    /// only, never the numbers (rust/tests/parallel_equivalence.rs).
    pub fn with_pool(mut self, pool: Pool) -> Self {
        self.pool = pool;
        self
    }

    pub fn rank(&self) -> usize {
        self.w_pinv.rows()
    }

    /// Whether the landmarks were adaptively (farthest-point) selected.
    pub fn adaptive(&self) -> bool {
        self.adaptive
    }

    /// The chosen landmark indices into the union cloud (`t < n` is
    /// `mu.points.row(t)`, else `nu.points.row(t - n)`). A pure function
    /// of the construction seed — what "landmark seed rides the
    /// envelope" means for sharded dispatch.
    pub fn landmarks(&self) -> &[usize] {
        &self.landmarks
    }

    /// Materialise the approximation (tests / small problems only).
    pub fn to_dense(&self) -> Mat {
        linalg::matmul(&linalg::matmul(&self.a, &self.w_pinv), &self.b)
    }

    /// The clamped signed log factors, composed on first log-domain use:
    /// `P = A·W⁺` (one rank-wide matmul), split by sign, logs floored at
    /// [`LOG_FLOOR`].
    fn log_view(&self) -> &LogView {
        self.log_view.get_or_init(|| {
            let p = linalg::matmul(&self.a, &self.w_pinv);
            let mut composed_min = f64::INFINITY;
            for i in 0..p.rows() {
                for &v in p.row(i) {
                    composed_min = composed_min.min(v as f64);
                }
            }
            for k in 0..self.b.rows() {
                for &v in self.b.row(k) {
                    composed_min = composed_min.min(v as f64);
                }
            }
            let floored_ln = |v: f32| if v > 0.0 { v.ln().max(LOG_FLOOR) } else { LOG_FLOOR };
            LogView {
                lpp: p.map(floored_ln),
                lpn: p.map(|v| floored_ln(-v)),
                lbt: self.b.transpose().map(floored_ln),
                composed_min,
            }
        })
    }

    /// Ones-probe gate for the log view, both directions: the clamped
    /// signed log apply must reproduce the plain f32 apply to
    /// [`LOG_VIEW_TOL`] relative, with every plain output positive and
    /// every log output finite. Evaluated once, lazily.
    fn log_view_agrees(&self) -> bool {
        *self.log_view_ok.get_or_init(|| {
            let agree = |plain: &[f32], logd: &[f64]| {
                plain.iter().zip(logd).all(|(&p, &l)| {
                    p > 0.0
                        && l.is_finite()
                        && ((l.exp() - p as f64) / p as f64).abs() <= LOG_VIEW_TOL
                })
            };
            let mut fwd = vec![0.0f64; self.rows()];
            self.apply_log(&vec![0.0f64; self.cols()], &mut fwd);
            if !agree(&self.apply(&vec![1.0f32; self.cols()]), &fwd) {
                return false;
            }
            let mut bwd = vec![0.0f64; self.cols()];
            self.apply_log_t(&vec![0.0f64; self.rows()], &mut bwd);
            agree(&self.apply_t(&vec![1.0f32; self.rows()]), &bwd)
        })
    }

    /// The paper's point: check whether this approximation behaves like a
    /// positive kernel. Probes `K v` **and** `Kᵀ u` with the uniform
    /// vector and `trials` random positive vectors (a fresh `v`/`u` pair
    /// per trial — a transpose-side-only negative entry triggers too),
    /// then checks that the clamped log view has not distorted the apply
    /// ([`LOG_VIEW_TOL`]). Returns [`Error::NotPositive`] in the regime
    /// where Sinkhorn with Nyström diverges.
    pub fn validate_positive(&self, rng: &mut Rng, trials: usize) -> Result<()> {
        let check = |v: &[f32], u: &[f32]| -> Result<()> {
            let out = self.apply(v);
            let out_t = self.apply_t(u);
            let min = out
                .iter()
                .chain(out_t.iter())
                .cloned()
                .fold(f32::INFINITY, f32::min);
            if min <= 0.0 {
                return Err(Error::NotPositive { min_entry: min as f64, rank: self.rank() });
            }
            Ok(())
        };
        check(&vec![1.0; self.cols()], &vec![1.0; self.rows()])?;
        for _ in 0..trials {
            let v: Vec<f32> = (0..self.cols()).map(|_| rng.uniform_in(0.01, 1.0) as f32).collect();
            let u: Vec<f32> = (0..self.rows()).map(|_| rng.uniform_in(0.01, 1.0) as f32).collect();
            check(&v, &u)?;
        }
        if !self.log_view_agrees() {
            return Err(Error::NotPositive {
                min_entry: self.log_view().composed_min,
                rank: self.rank(),
            });
        }
        Ok(())
    }
}

impl KernelOp for NystromKernel {
    fn rows(&self) -> usize {
        self.a.rows()
    }

    fn cols(&self) -> usize {
        self.b.cols()
    }

    fn apply_into(&self, v: &[f32], out: &mut [f32]) {
        let mut s = self.scratch.lock().unwrap();
        let (t1, t2) = &mut *s;
        linalg::matvec_into_pooled(&self.b, v, t1, &self.pool);
        linalg::matvec_into_pooled(&self.w_pinv, t1, t2, &self.pool);
        linalg::matvec_into_pooled(&self.a, t2, out, &self.pool);
    }

    fn apply_t_into(&self, u: &[f32], out: &mut [f32]) {
        let mut s = self.scratch.lock().unwrap();
        let (t1, t2) = &mut *s;
        linalg::matvec_t_into_pooled(&self.a, u, t1, &self.pool);
        linalg::matvec_t_into_pooled(&self.w_pinv, t1, t2, &self.pool);
        linalg::matvec_t_into_pooled(&self.b, t2, out, &self.pool);
    }

    /// Fused multi-pair apply: three column-blocked mat-mats with one
    /// stream over each factor for all B pairs. Each pair row is bitwise
    /// identical to [`KernelOp::apply_into`] on that pair's vector at
    /// every pool size (the column-blocked kernels share row kernels and
    /// chunk grids with the vector ones).
    fn apply_batch_into(&self, vs: &Mat, out: &mut Mat) {
        let r = self.rank();
        let mut m1 = Mat::zeros(vs.rows(), r);
        let mut m2 = Mat::zeros(vs.rows(), r);
        linalg::matmat_into_pooled(&self.b, vs, &mut m1, &self.pool);
        linalg::matmat_into_pooled(&self.w_pinv, &m1, &mut m2, &self.pool);
        linalg::matmat_into_pooled(&self.a, &m2, out, &self.pool);
    }

    fn apply_batch_t_into(&self, us: &Mat, out: &mut Mat) {
        let r = self.rank();
        let mut m1 = Mat::zeros(us.rows(), r);
        let mut m2 = Mat::zeros(us.rows(), r);
        linalg::matmat_t_into_pooled(&self.a, us, &mut m1, &self.pool);
        linalg::matmat_t_into_pooled(&self.w_pinv, &m1, &mut m2, &self.pool);
        linalg::matmat_t_into_pooled(&self.b, &m2, out, &self.pool);
    }

    fn min_entry(&self) -> f64 {
        // Estimate by probing; can be ≤ 0 (that's the point).
        let e = self.apply(&vec![1.0; self.cols()]);
        e.iter().cloned().fold(f32::INFINITY, f32::min) as f64 / self.cols() as f64
    }

    fn flops_per_apply(&self) -> u64 {
        let r = self.rank() as u64;
        2 * r * (self.rows() as u64 + self.cols() as u64) + 2 * r * r
    }

    fn label(&self) -> String {
        format!(
            "Nys({}r={} {}x{})",
            if self.adaptive { "adaptive " } else { "" },
            self.rank(),
            self.rows(),
            self.cols()
        )
    }

    /// The clamped signed log view — gated on the ones probe: `None`
    /// whenever clamping (or lost positivity) would distort the apply,
    /// so escalation fails typed instead of converging on the wrong
    /// kernel.
    fn as_log_kernel(&self) -> Option<&dyn LogKernelOp> {
        if self.log_view_agrees() {
            Some(self)
        } else {
            None
        }
    }
}

impl LogKernelOp for NystromKernel {
    fn shape(&self) -> (usize, usize) {
        (self.rows(), self.cols())
    }

    /// `logsumexp_j(log K_ij + t_j)` through the clamped signed split:
    ///
    /// ```text
    /// s   = ln(B eᵗ)                    (exact: B ≥ 0)
    /// out = ln(P⁺ eˢ) ⊖ ln(P⁻ eˢ)       (signed combine, f64)
    /// ```
    ///
    /// Three skinny logsumexp matvecs, O(rank·(n+m)) time, O(rank) extra
    /// memory. Rows whose negative part dominates produce `-inf`/NaN,
    /// which the log-domain solver reports as a typed divergence.
    fn apply_log(&self, t: &[f64], out: &mut [f64]) {
        let lv = self.log_view();
        let mut s = vec![0.0f64; self.rank()];
        linalg::lse_matvec_t_into_pooled(&lv.lbt, 1.0, t, &mut s, &self.pool);
        let mut pos = vec![0.0f64; out.len()];
        let mut neg = vec![0.0f64; out.len()];
        linalg::lse_matvec_into_pooled(&lv.lpp, 1.0, &s, &mut pos, &self.pool);
        linalg::lse_matvec_into_pooled(&lv.lpn, 1.0, &s, &mut neg, &self.pool);
        signed_combine(&pos, &neg, out);
    }

    fn apply_log_t(&self, u: &[f64], out: &mut [f64]) {
        let lv = self.log_view();
        let mut sp = vec![0.0f64; self.rank()];
        let mut sn = vec![0.0f64; self.rank()];
        linalg::lse_matvec_t_into_pooled(&lv.lpp, 1.0, u, &mut sp, &self.pool);
        linalg::lse_matvec_t_into_pooled(&lv.lpn, 1.0, u, &mut sn, &self.pool);
        let mut pos = vec![0.0f64; out.len()];
        let mut neg = vec![0.0f64; out.len()];
        linalg::lse_matvec_into_pooled(&lv.lbt, 1.0, &sp, &mut pos, &self.pool);
        linalg::lse_matvec_into_pooled(&lv.lbt, 1.0, &sn, &mut neg, &self.pool);
        signed_combine(&pos, &neg, out);
    }

    // Batch log applies use the trait's per-pair loop default, which is
    // trivially bitwise identical per pair to the vector applies.

    fn describe(&self) -> String {
        format!(
            "Nys-log({}r={} {}x{})",
            if self.adaptive { "adaptive " } else { "" },
            self.rank(),
            self.rows(),
            self.cols()
        )
    }
}

/// `out_i = pos_i ⊖ neg_i = pos_i + ln(1 − exp(neg_i − pos_i))`:
/// the signed logsumexp combine. `-inf` where the parts cancel exactly,
/// NaN where the negative part dominates — both non-finite, both caught
/// by the log-domain solver's finiteness checks.
fn signed_combine(pos: &[f64], neg: &[f64], out: &mut [f64]) {
    for ((&p, &n), o) in pos.iter().zip(neg).zip(out.iter_mut()) {
        *o = p + (-((n - p).exp())).ln_1p();
    }
}

/// Union cloud as one (n+m, dim) matrix (mu rows first).
fn union_matrix(mu: &Measure, nu: &Measure) -> Mat {
    let d = mu.dim();
    Mat::from_fn(mu.len() + nu.len(), d, |t, j| {
        if t < mu.len() { mu.points.row(t)[j] } else { nu.points.row(t - mu.len())[j] }
    })
}

/// Squared Euclidean norm per row, accumulated in f64.
fn row_sq_norms(points: &Mat) -> Vec<f64> {
    (0..points.rows())
        .map(|i| points.row(i).iter().map(|&v| (v as f64) * (v as f64)).sum())
        .collect()
}

/// Greedy farthest-point (k-center) landmark selection over the union
/// cloud. One seeded uniform draw picks the first landmark; every later
/// pick maximises the squared distance to the chosen set, ties to the
/// lowest index — deterministic given the seed at any pool size. The
/// per-round distance update is one pooled matvec (`⟨p_i, l⟩` for all i).
fn farthest_point_landmarks(
    union: &Mat,
    norms: &[f64],
    rank: usize,
    rng: &mut Rng,
    pool: &Pool,
) -> Vec<usize> {
    let total = union.rows();
    debug_assert!(rank <= total);
    let mut chosen = Vec::with_capacity(rank);
    let mut taken = vec![false; total];
    let first = rng.uniform_usize(total);
    chosen.push(first);
    taken[first] = true;
    let mut mind = vec![f64::INFINITY; total];
    let mut dots = vec![0.0f32; total];
    while chosen.len() < rank {
        let l = *chosen.last().unwrap();
        linalg::matvec_into_pooled(union, union.row(l), &mut dots, pool);
        let ln = norms[l];
        for (i, md) in mind.iter_mut().enumerate() {
            let d2 = (norms[i] + ln - 2.0 * dots[i] as f64).max(0.0);
            if d2 < *md {
                *md = d2;
            }
        }
        let mut best = usize::MAX;
        let mut best_d = f64::NEG_INFINITY;
        for (i, (&md, &tk)) in mind.iter().zip(&taken).enumerate() {
            if !tk && md > best_d {
                best_d = md;
                best = i;
            }
        }
        chosen.push(best);
        taken[best] = true;
    }
    chosen
}

/// Gibbs block `K(points, lmk)` (points.rows × lmk.rows): the cross
/// inner products run as one pooled column-blocked mat-mat, then
/// `exp(−d²/eps)` per entry with the same `exp(LOG_FLOOR)` positivity
/// floor as the dense kernel (f32-positive entries; tiny-eps failures
/// surface in the marginals, not via 0-division).
fn gibbs_block(
    points: &Mat,
    norms: &[f64],
    lmk: &Mat,
    lnorms: &[f64],
    eps: f64,
    pool: &Pool,
) -> Mat {
    let n = points.rows();
    let r = lmk.rows();
    let mut dots = Mat::zeros(r, n);
    linalg::matmat_into_pooled(points, lmk, &mut dots, pool);
    let mut out = Mat::zeros(n, r);
    for i in 0..n {
        let row = out.row_mut(i);
        for (k, cell) in row.iter_mut().enumerate() {
            let d2 = (norms[i] + lnorms[k] - 2.0 * (dots[(k, i)] as f64)).max(0.0);
            *cell = ((-d2 / eps).max(LOG_FLOOR as f64)).exp() as f32;
        }
    }
    out
}

/// Ridge-regularised inverse via Gauss–Jordan in f64 (rank x rank, small).
///
/// The landmark block K_LL is severely ill-conditioned at large eps (all
/// entries near 1), so the elimination runs in f64 and the ridge is scaled
/// to the matrix's mean diagonal — otherwise f32 cancellation noise in
/// W^+ dominates the whole Nyström apply.
fn ridge_inverse(w: &Mat, rel_ridge: f64) -> Mat {
    let n = w.rows();
    assert_eq!(w.cols(), n);
    let mean_diag: f64 =
        (0..n).map(|i| w[(i, i)] as f64).sum::<f64>() / n as f64;
    let ridge = rel_ridge * mean_diag.max(1e-30);
    // Augmented [W + ridge I | I] in f64.
    let mut aug = vec![0.0f64; n * 2 * n];
    let idx = |i: usize, j: usize| i * 2 * n + j;
    for i in 0..n {
        for j in 0..n {
            aug[idx(i, j)] = w[(i, j)] as f64 + if i == j { ridge } else { 0.0 };
        }
        aug[idx(i, n + i)] = 1.0;
    }
    for col in 0..n {
        // Partial pivot.
        let mut piv = col;
        for i in col + 1..n {
            if aug[idx(i, col)].abs() > aug[idx(piv, col)].abs() {
                piv = i;
            }
        }
        if piv != col {
            for j in 0..2 * n {
                aug.swap(idx(col, j), idx(piv, j));
            }
        }
        let p = aug[idx(col, col)];
        let p = if p.abs() < 1e-300 { 1e-300_f64.copysign(p) } else { p };
        for j in 0..2 * n {
            aug[idx(col, j)] /= p;
        }
        for i in 0..n {
            if i == col {
                continue;
            }
            let f = aug[idx(i, col)];
            if f == 0.0 {
                continue;
            }
            for j in 0..2 * n {
                aug[idx(i, j)] -= f * aug[idx(col, j)];
            }
        }
    }
    Mat::from_fn(n, n, |i, j| aug[idx(i, n + j)] as f32)
}

#[cfg(test)]
mod tests {
    use super::super::DenseKernel;
    use super::*;
    use crate::data;

    fn clouds(seed: u64, n: usize) -> (Measure, Measure) {
        let mut rng = Rng::seed_from(seed);
        data::gaussian_blobs(n, &mut rng)
    }

    /// Test-only construction from explicit factors (same module, so the
    /// private fields are reachable): `K = a · w_pinv · b`.
    fn kernel_from_parts(a: Mat, w_pinv: Mat, b: Mat) -> NystromKernel {
        let r = w_pinv.rows();
        NystromKernel {
            a,
            w_pinv,
            b,
            eps: 1.0,
            adaptive: false,
            landmarks: Vec::new(),
            scratch: std::sync::Mutex::new((vec![0.0; r], vec![0.0; r])),
            pool: Pool::serial(),
            log_view: std::sync::OnceLock::new(),
            log_view_ok: std::sync::OnceLock::new(),
        }
    }

    #[test]
    fn ridge_inverse_inverts() {
        let w = Mat::from_rows(&[vec![2.0, 1.0], vec![1.0, 3.0]]);
        let wi = ridge_inverse(&w, 0.0);
        let prod = linalg::matmul(&w, &wi);
        assert!((prod[(0, 0)] - 1.0).abs() < 1e-4);
        assert!((prod[(1, 1)] - 1.0).abs() < 1e-4);
        assert!(prod[(0, 1)].abs() < 1e-4);
    }

    #[test]
    fn nystrom_accurate_at_large_eps() {
        // Large eps -> K is near low-rank -> Nyström is accurate: the
        // regime where the paper says Nys and RF both work.
        let (mu, nu) = clouds(9, 40);
        let mut rng = Rng::seed_from(10);
        let nk = NystromKernel::from_measures(&mu, &nu, 5.0, 20, &mut rng);
        let dk = DenseKernel::from_measures(&mu, &nu, 5.0);
        let approx = nk.to_dense();
        let mut max_rel = 0.0f64;
        for i in 0..40 {
            for j in 0..40 {
                let rel = ((approx[(i, j)] - dk.k[(i, j)]).abs() / dk.k[(i, j)]) as f64;
                max_rel = max_rel.max(rel);
            }
        }
        // The 1e-3 relative ridge biases the approximation slightly; ~5%
        // max relative entry error at rank n/4 is the expected regime.
        assert!(max_rel < 0.08, "max rel err {max_rel}");
        assert!(nk.validate_positive(&mut rng, 3).is_ok());
    }

    #[test]
    fn adaptive_beats_or_matches_uniform_on_entry_error() {
        // Farthest-point landmarks cover the cloud; at matched rank the
        // adaptive approximation should not be substantially worse than
        // uniform on max relative entry error (usually better).
        let (mu, nu) = clouds(21, 40);
        let dk = DenseKernel::from_measures(&mu, &nu, 5.0);
        let max_rel = |nk: &NystromKernel| {
            let approx = nk.to_dense();
            let mut worst = 0.0f64;
            for i in 0..40 {
                for j in 0..40 {
                    let rel = ((approx[(i, j)] - dk.k[(i, j)]).abs() / dk.k[(i, j)]) as f64;
                    worst = worst.max(rel);
                }
            }
            worst
        };
        let mut rng_u = Rng::seed_from(22);
        let uni = NystromKernel::from_measures(&mu, &nu, 5.0, 12, &mut rng_u);
        let mut rng_a = Rng::seed_from(22);
        let ada = NystromKernel::from_measures_adaptive(&mu, &nu, 5.0, 12, &mut rng_a);
        assert!(ada.adaptive() && !uni.adaptive());
        let (eu, ea) = (max_rel(&uni), max_rel(&ada));
        assert!(ea < eu * 2.0 + 0.02, "adaptive {ea} vs uniform {eu}");
        assert!(ea < 0.5, "adaptive approximation unusable: {ea}");
    }

    #[test]
    fn adaptive_landmarks_are_seed_deterministic_and_spread() {
        let (mu, nu) = clouds(23, 30);
        let mk = |seed: u64| {
            let mut rng = Rng::seed_from(seed);
            NystromKernel::from_measures_adaptive(&mu, &nu, 1.0, 10, &mut rng)
        };
        let k1 = mk(5);
        let k2 = mk(5);
        assert_eq!(k1.landmarks(), k2.landmarks(), "same seed, same landmarks");
        // No duplicate landmarks (farthest-point never re-picks).
        let mut seen = k1.landmarks().to_vec();
        seen.sort_unstable();
        seen.dedup();
        assert_eq!(seen.len(), k1.landmarks().len());
        // A different seed moves the (uniform) first pick and thus the set.
        let k3 = mk(6);
        assert!(
            k1.landmarks() != k3.landmarks() || k1.landmarks().len() <= 1,
            "different seed should generally select differently"
        );
    }

    #[test]
    fn nystrom_loses_positivity_at_small_eps() {
        // Small eps -> K is effectively full-rank -> low-rank Nyström
        // produces non-positive outputs: the failure the paper fixes.
        let (mu, nu) = clouds(11, 60);
        let mut rng = Rng::seed_from(12);
        let nk = NystromKernel::from_measures(&mu, &nu, 0.01, 10, &mut rng);
        let err = nk.validate_positive(&mut rng, 5);
        assert!(err.is_err(), "expected positivity failure at eps=0.01, rank 10");
        if let Err(Error::NotPositive { min_entry, .. }) = err {
            assert!(min_entry <= 0.0);
        }
        // And the log view is gated off: escalation cannot silently
        // converge on the distorted clamped kernel.
        assert!(nk.as_log_kernel().is_none());
    }

    #[test]
    fn nystrom_apply_matches_dense_materialisation() {
        let (mu, nu) = clouds(13, 25);
        let mut rng = Rng::seed_from(14);
        let nk = NystromKernel::from_measures(&mu, &nu, 2.0, 12, &mut rng);
        let dense = nk.to_dense();
        let v: Vec<f32> = (0..25).map(|i| (i as f32 * 0.07).sin().abs() + 0.1).collect();
        // Tolerance reflects f32 matvecs against W^+ entries of size
        // O(1/ridge): the two evaluation orders agree to ~1e-3 relative.
        let want = linalg::matvec(&dense, &v);
        let scale = (linalg::l1_norm(&want) / 25.0).max(1.0);
        let got = nk.apply(&v);
        assert!(linalg::max_abs_diff(&got, &want) < 1e-3 * scale);
        let got_t = nk.apply_t(&v);
        let want_t = linalg::matvec_t(&dense, &v);
        assert!(linalg::max_abs_diff(&got_t, &want_t) < 1e-3 * scale);
    }

    #[test]
    fn batched_applies_match_vector_applies_bitwise() {
        let (mu, nu) = clouds(31, 20);
        let mut rng = Rng::seed_from(32);
        let nk = NystromKernel::from_measures(&mu, &nu, 2.0, 8, &mut rng);
        let b = 3;
        let vs = Mat::from_fn(b, nu.len(), |p, j| 0.1 + 0.01 * (p * 7 + j) as f32);
        let us = Mat::from_fn(b, mu.len(), |p, i| 0.2 + 0.01 * (p * 5 + i) as f32);
        let mut out = Mat::zeros(b, nk.rows());
        nk.apply_batch_into(&vs, &mut out);
        let mut out_t = Mat::zeros(b, nk.cols());
        nk.apply_batch_t_into(&us, &mut out_t);
        for p in 0..b {
            let want = nk.apply(vs.row(p));
            let want_t = nk.apply_t(us.row(p));
            for (got, want) in out.row(p).iter().zip(&want) {
                assert_eq!(got.to_bits(), want.to_bits(), "pair {p}");
            }
            for (got, want) in out_t.row(p).iter().zip(&want_t) {
                assert_eq!(got.to_bits(), want.to_bits(), "pair {p} ^T");
            }
        }
    }

    #[test]
    fn log_view_matches_plain_apply_where_sound() {
        // Where the approximation is positive, exp(apply_log(ln v)) must
        // track the plain apply: the two views are the same operator, so
        // escalation and annealing land on the same numbers.
        let (mu, nu) = clouds(33, 30);
        let mut rng = Rng::seed_from(34);
        let nk = NystromKernel::from_measures(&mu, &nu, 5.0, 15, &mut rng);
        assert!(nk.as_log_kernel().is_some(), "sound regime must expose the log view");
        let v: Vec<f32> = (0..30).map(|j| 0.2 + 0.01 * j as f32).collect();
        let plain = nk.apply(&v);
        let log_v: Vec<f64> = v.iter().map(|&x| (x as f64).ln()).collect();
        let mut log_out = vec![0.0f64; 30];
        nk.apply_log(&log_v, &mut log_out);
        for i in 0..30 {
            let want = log_out[i].exp();
            let rel = ((plain[i] as f64) - want).abs() / want.abs().max(1e-30);
            assert!(rel < 1e-2, "row {i}: plain {} vs exp(log) {}", plain[i], want);
        }
        // Transposed direction too.
        let u: Vec<f32> = (0..30).map(|i| 0.3 + 0.005 * i as f32).collect();
        let plain_t = nk.apply_t(&u);
        let log_u: Vec<f64> = u.iter().map(|&x| (x as f64).ln()).collect();
        let mut log_out_t = vec![0.0f64; 30];
        nk.apply_log_t(&log_u, &mut log_out_t);
        for j in 0..30 {
            let want = log_out_t[j].exp();
            let rel = ((plain_t[j] as f64) - want).abs() / want.abs().max(1e-30);
            assert!(rel < 1e-2, "col {j}");
        }
    }

    #[test]
    fn validate_positive_catches_transpose_side_negative() {
        // Regression for the all-ones transpose probe bug: a kernel whose
        // forward applies stay positive on every positive probe, and whose
        // *uniform* transpose probe stays positive, but where a random
        // positive u drives a transpose output negative. Only probing
        // `Kᵀ u` with the trial vector catches it.
        //
        // K = [[1, -0.0099], [0.0099, 0.01]]:
        //   K v  = (v1 − 0.0099 v2, 0.0099 v1 + 0.01 v2) > 0 on [0.01,1]²
        //   Kᵀ 1 = (1.0099, 0.0001) > 0           (the old probe passes)
        //   Kᵀ u = (…, −0.0099 u1 + 0.01 u2) < 0 iff u2 < 0.99 u1
        let eye = Mat::from_rows(&[vec![1.0, 0.0], vec![0.0, 1.0]]);
        let k = Mat::from_rows(&[vec![1.0, -0.0099], vec![0.0099, 0.01]]);
        let nk = kernel_from_parts(k, eye.clone(), eye);
        // The directions the buggy probe exercised stay positive.
        assert!(nk.apply(&[1.0, 1.0]).iter().all(|&x| x > 0.0));
        assert!(nk.apply_t(&[1.0, 1.0]).iter().all(|&x| x > 0.0));
        // Enough trials that some u with u2 < 0.99 u1 is drawn (each trial
        // hits that half-plane with probability ~1/2).
        let mut rng = Rng::seed_from(35);
        let err = nk.validate_positive(&mut rng, 64);
        match err {
            Err(Error::NotPositive { min_entry, .. }) => {
                assert!(min_entry <= 0.0, "negative transpose entry, got {min_entry}")
            }
            other => panic!("expected NotPositive from a transpose-side trial, got {other:?}"),
        }
    }

    #[test]
    fn uniform_landmarks_ride_the_seed() {
        let (mu, nu) = clouds(41, 25);
        let mk = |seed: u64| {
            let mut rng = Rng::seed_from(seed);
            NystromKernel::from_measures(&mu, &nu, 1.0, 6, &mut rng)
        };
        let (k1, k2) = (mk(9), mk(9));
        assert_eq!(k1.landmarks(), k2.landmarks());
        // Identical landmarks + deterministic pooled construction ⇒
        // bitwise-identical applies: the sharded-dispatch contract.
        let v = vec![0.5f32; nu.len()];
        let (o1, o2) = (k1.apply(&v), k2.apply(&v));
        for (x, y) in o1.iter().zip(&o2) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }
}

#[cfg(test)]
mod debug_nystrom {
    use super::*;
    use crate::data;
    use crate::rng::Rng;

    #[test]
    #[ignore]
    fn probe() {
        for eps in [0.5f64, 1.0] {
            for rank in [100usize, 600] {
                let mut rng = Rng::seed_from(0);
                let (mu, nu) = data::gaussian_blobs(2000, &mut rng);
                let nk = NystromKernel::from_measures(&mu, &nu, eps, rank, &mut rng);
                let out = nk.apply(&vec![1.0; nu.len()]);
                let min = out.iter().cloned().fold(f32::INFINITY, f32::min);
                let neg = out.iter().filter(|&&x| x <= 0.0).count();
                println!("eps={eps} rank={rank}: min(K1)={min:e} negatives={neg}/{}", out.len());
            }
        }
    }
}

#[cfg(test)]
mod debug_nystrom2 {
    use super::*;
    use crate::config::SinkhornConfig;
    use crate::data;
    use crate::rng::Rng;
    use crate::sinkhorn::sinkhorn;

    #[test]
    #[ignore]
    fn probe_solve() {
        for eps in [1.0f64, 2.0, 5.0] {
            for rank in [300usize, 1000] {
                let mut rng = Rng::seed_from(3);
                let (mu, nu) = data::gaussian_blobs(2000, &mut rng);
                let nk = NystromKernel::from_measures(&mu, &nu, eps, rank, &mut rng);
                let cfg = SinkhornConfig {
                    epsilon: eps,
                    max_iters: 2000,
                    tol: 1e-4,
                    check_every: 10,
                    threads: 1,
                    stabilize: false,
                    max_batch: 1,
                    anneal: None,
                    anneal_decay: 0.5,
                    symmetric: None,
                };
                match sinkhorn(&nk, &mu.weights, &nu.weights, &cfg) {
                    Ok(s) => println!(
                        "eps={eps} rank={rank}: OK obj={:.4} iters={}",
                        s.objective, s.iterations
                    ),
                    Err(e) => println!("eps={eps} rank={rank}: FAIL {e:.60}"),
                }
            }
        }
    }
}
