//! Runtime-dispatched SIMD execution layer under the linalg core.
//!
//! Every hot kernel in `linalg::ops` — the per-row dot, the saxpy row
//! blocks behind the transposed matvecs, the logsumexp row/column
//! reductions, and the feature-evaluation dots — is implemented here
//! twice:
//!
//! * a **portable scalar arm**: the pre-SIMD code, kept verbatim, and
//! * an **AVX2+FMA arm**: `#[target_feature]` kernels using explicit
//!   256-bit intrinsics, with the f64 `exp`/`ln` calls of the logsumexp
//!   path replaced by the vectorised polynomials in
//!   [`crate::special::vexp`].
//!
//! ## Dispatch matrix
//!
//! | target | detected | arm |
//! |--------|----------|-----|
//! | x86_64 | AVX2 **and** FMA | `Avx2Fma` |
//! | x86_64 | otherwise        | `Scalar` |
//! | other  | —                | `Scalar` |
//!
//! Detection runs once per process ([`active_level`], cached). The env
//! override `LINEAR_SINKHORN_SIMD=scalar` forces the portable arm (for
//! the CI scalar test leg and cross-machine-reproducible runs);
//! `=avx2` requests the vector arm (honoured only when the CPU
//! supports it); anything else auto-detects.
//!
//! ## Determinism contract
//!
//! Dispatch is process-global and every kernel's arithmetic order is
//! fixed *within* an arm (fixed block sizes, fixed lane-reduction
//! orders), so the repo's bitwise thread-count-determinism invariant
//! holds **per arm**: on either arm, 1 thread and N threads produce
//! identical bits (`rust/tests/parallel_equivalence.rs` asserts this on
//! both). Across arms, results agree to the documented kernel
//! tolerances (FMA keeps products unrounded and the lane reductions
//! re-associate) — the arm is part of a run's reproducibility key, like
//! the compiler version, and `LINEAR_SINKHORN_SIMD=scalar` pins it.
//!
//! The f32-lanes/f64-block-accumulate accuracy contract of the plain
//! matvec (EXPERIMENTS.md §Perf) carries over unchanged: the AVX2
//! `row_dot` keeps its partial sums in f32 lanes within each 64-element
//! block and accumulates block totals in f64, exactly like the scalar
//! arm — only the lane count per block differs (32 vs 8).

use super::Mat;
use std::ops::Range;
use std::sync::OnceLock;

#[cfg(target_arch = "x86_64")]
use crate::special::vexp;
#[cfg(target_arch = "x86_64")]
use std::arch::x86_64::*;

/// A dispatch arm of the SIMD core.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar kernels (the pre-SIMD code, kept verbatim).
    Scalar,
    /// AVX2 + FMA kernels (x86_64 only, runtime-detected).
    Avx2Fma,
}

impl SimdLevel {
    /// Short label for benches and BENCH_*.json rows.
    pub fn label(self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2Fma => "avx2+fma",
        }
    }

    /// Demote to [`SimdLevel::Scalar`] when the CPU cannot run this arm.
    ///
    /// Every public `*_at` entry point sanitises its level argument once,
    /// so explicitly constructing [`SimdLevel::Avx2Fma`] (tests, benches)
    /// is always safe — on a machine without AVX2+FMA it just runs the
    /// scalar arm.
    pub fn sanitize(self) -> SimdLevel {
        match self {
            SimdLevel::Avx2Fma if !avx2_available() => SimdLevel::Scalar,
            lvl => lvl,
        }
    }
}

/// Whether the AVX2+FMA arm can run on this machine.
#[cfg(target_arch = "x86_64")]
pub fn avx2_available() -> bool {
    is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma")
}

/// Whether the AVX2+FMA arm can run on this machine.
#[cfg(not(target_arch = "x86_64"))]
pub fn avx2_available() -> bool {
    false
}

static LEVEL: OnceLock<SimdLevel> = OnceLock::new();

/// The process-wide dispatch arm: runtime CPU detection, overridable via
/// `LINEAR_SINKHORN_SIMD` (see the module docs). Cached on first call —
/// changing the env var afterwards has no effect, which is what keeps
/// the arm constant across every thread of a run.
pub fn active_level() -> SimdLevel {
    *LEVEL.get_or_init(|| match std::env::var("LINEAR_SINKHORN_SIMD").ok().as_deref() {
        Some("scalar" | "portable" | "off" | "0") => SimdLevel::Scalar,
        _ => {
            if avx2_available() {
                SimdLevel::Avx2Fma
            } else {
                SimdLevel::Scalar
            }
        }
    })
}

// ---------------------------------------------------------------------
// row_dot: one row of the blocked matvec accumulation scheme.
// ---------------------------------------------------------------------

/// One row dot of the blocked accumulation scheme (f32 partial lanes
/// within 64-element blocks, f64 across blocks — EXPERIMENTS.md §Perf).
/// Shared by the serial and pooled matvecs of both arms, so on a given
/// arm every caller produces bitwise-identical rows.
#[inline]
pub(crate) fn row_dot(level: SimdLevel, row: &[f32], v: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), v.len());
    match level {
        SimdLevel::Scalar => row_dot_scalar(row, v),
        SimdLevel::Avx2Fma => row_dot_avx2_call(row, v),
    }
}

/// The portable arm, verbatim from the pre-SIMD `ops.rs`.
fn row_dot_scalar(row: &[f32], v: &[f32]) -> f32 {
    const BLOCK: usize = 64;
    let mut acc = 0.0f64;
    let mut rb = row.chunks_exact(BLOCK);
    let mut vb = v.chunks_exact(BLOCK);
    for (r64, v64) in (&mut rb).zip(&mut vb) {
        // 8 independent f32 partials over the 64-element block.
        let mut p = [0.0f32; 8];
        for (rc, vc) in r64.chunks_exact(8).zip(v64.chunks_exact(8)) {
            for l in 0..8 {
                p[l] += rc[l] * vc[l];
            }
        }
        acc += p.iter().map(|&x| x as f64).sum::<f64>();
    }
    for (r, w) in rb.remainder().iter().zip(vb.remainder()) {
        acc += (*r as f64) * (*w as f64);
    }
    acc as f32
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn row_dot_avx2_call(row: &[f32], v: &[f32]) -> f32 {
    // SAFETY: `Avx2Fma` levels are sanitised at the public entry points.
    unsafe { row_dot_avx2(row, v) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn row_dot_avx2_call(row: &[f32], v: &[f32]) -> f32 {
    row_dot_scalar(row, v)
}

/// Lane-order f64 sum of the 4 f64 lanes (fixed reduction tree).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn hsum_pd(x: __m256d) -> f64 {
    let lo = _mm256_castpd256_pd128(x);
    let hi = _mm256_extractf128_pd::<1>(x);
    let s = _mm_add_pd(lo, hi);
    let sh = _mm_unpackhi_pd(s, s);
    _mm_cvtsd_f64(_mm_add_sd(s, sh))
}

/// Widen 8 f32 lanes to 4 f64 lanes (low+high half pairs, fixed order).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn widen_ps_sum_pd(x: __m256) -> __m256d {
    _mm256_add_pd(
        _mm256_cvtps_pd(_mm256_castps256_ps128(x)),
        _mm256_cvtps_pd(_mm256_extractf128_ps::<1>(x)),
    )
}

/// AVX2 arm: 4 independent 8-lane FMA accumulators per 64-element block
/// (32 f32 partials), block totals accumulated in f64 on a fixed
/// reduction tree — the same f32-lanes/f64-blocks contract as the scalar
/// arm with more lanes and fused multiplies.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn row_dot_avx2(row: &[f32], v: &[f32]) -> f32 {
    const BLOCK: usize = 64;
    let n = row.len();
    let nb = n - n % BLOCK;
    let rp = row.as_ptr();
    let vp = v.as_ptr();
    let mut acc = 0.0f64;
    let mut i = 0;
    while i < nb {
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        let mut c = 0;
        while c < BLOCK {
            let o = i + c;
            a0 = _mm256_fmadd_ps(_mm256_loadu_ps(rp.add(o)), _mm256_loadu_ps(vp.add(o)), a0);
            a1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(rp.add(o + 8)),
                _mm256_loadu_ps(vp.add(o + 8)),
                a1,
            );
            a2 = _mm256_fmadd_ps(
                _mm256_loadu_ps(rp.add(o + 16)),
                _mm256_loadu_ps(vp.add(o + 16)),
                a2,
            );
            a3 = _mm256_fmadd_ps(
                _mm256_loadu_ps(rp.add(o + 24)),
                _mm256_loadu_ps(vp.add(o + 24)),
                a3,
            );
            c += 32;
        }
        let t01 = _mm256_add_pd(widen_ps_sum_pd(a0), widen_ps_sum_pd(a1));
        let t23 = _mm256_add_pd(widen_ps_sum_pd(a2), widen_ps_sum_pd(a3));
        acc += hsum_pd(_mm256_add_pd(t01, t23));
        i += BLOCK;
    }
    while i < n {
        acc += (*rp.add(i) as f64) * (*vp.add(i) as f64);
        i += 1;
    }
    acc as f32
}

// ---------------------------------------------------------------------
// saxpy_rows: the transposed-matvec row accumulation.
// ---------------------------------------------------------------------

/// Accumulate `out += a[rows]^T @ v[rows]` (`out` pre-zeroed or carrying
/// a prior partial). The scalar arm is the 4-row saxpy blocking; the
/// AVX2 arm widens to an 8-row × 8-column register-tiled microkernel.
/// Shared by the serial and pooled transposed matvecs of both arms.
pub(crate) fn saxpy_rows(
    level: SimdLevel,
    a: &Mat,
    v: &[f32],
    rows: Range<usize>,
    out: &mut [f32],
) {
    match level {
        SimdLevel::Scalar => saxpy_rows_scalar(a, v, rows, out),
        SimdLevel::Avx2Fma => saxpy_rows_avx2_call(a, v, rows, out),
    }
}

/// The portable arm, verbatim from the pre-SIMD `ops.rs`.
fn saxpy_rows_scalar(a: &Mat, v: &[f32], rows: Range<usize>, out: &mut [f32]) {
    let (lo, hi) = (rows.start, rows.end);
    let k = a.cols();
    let data = a.data();
    let mut i = lo;
    while i + 4 <= hi {
        let (v0, v1, v2, v3) = (v[i], v[i + 1], v[i + 2], v[i + 3]);
        let r0 = &data[i * k..(i + 1) * k];
        let r1 = &data[(i + 1) * k..(i + 2) * k];
        let r2 = &data[(i + 2) * k..(i + 3) * k];
        let r3 = &data[(i + 3) * k..(i + 4) * k];
        for j in 0..k {
            out[j] += r0[j] * v0 + r1[j] * v1 + r2[j] * v2 + r3[j] * v3;
        }
        i += 4;
    }
    while i < hi {
        let vi = v[i];
        if vi != 0.0 {
            let row = a.row(i);
            for (o, &r) in out.iter_mut().zip(row) {
                *o += r * vi;
            }
        }
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
fn saxpy_rows_avx2_call(a: &Mat, v: &[f32], rows: Range<usize>, out: &mut [f32]) {
    // SAFETY: `Avx2Fma` levels are sanitised at the public entry points.
    unsafe { saxpy_rows_avx2(a, v, rows.start, rows.end, out) }
}

#[cfg(not(target_arch = "x86_64"))]
fn saxpy_rows_avx2_call(a: &Mat, v: &[f32], rows: Range<usize>, out: &mut [f32]) {
    saxpy_rows_scalar(a, v, rows, out)
}

/// One 8-row × 8-column FMA tile step plus tails; the shared body of the
/// vector and multi-pair AVX2 saxpy (identical per-output arithmetic is
/// what keeps fused batch applies bitwise equal per pair).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn saxpy_block8_avx2(r: *const f32, k: usize, c: &[f32], op: *mut f32) {
    let c0 = _mm256_set1_ps(c[0]);
    let c1 = _mm256_set1_ps(c[1]);
    let c2 = _mm256_set1_ps(c[2]);
    let c3 = _mm256_set1_ps(c[3]);
    let c4 = _mm256_set1_ps(c[4]);
    let c5 = _mm256_set1_ps(c[5]);
    let c6 = _mm256_set1_ps(c[6]);
    let c7 = _mm256_set1_ps(c[7]);
    let mut j = 0;
    while j + 8 <= k {
        let mut o = _mm256_loadu_ps(op.add(j));
        o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(j)), c0, o);
        o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(k + j)), c1, o);
        o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(2 * k + j)), c2, o);
        o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(3 * k + j)), c3, o);
        o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(4 * k + j)), c4, o);
        o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(5 * k + j)), c5, o);
        o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(6 * k + j)), c6, o);
        o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(7 * k + j)), c7, o);
        _mm256_storeu_ps(op.add(j), o);
        j += 8;
    }
    while j < k {
        let mut s = *op.add(j);
        s += *r.add(j) * c[0];
        s += *r.add(k + j) * c[1];
        s += *r.add(2 * k + j) * c[2];
        s += *r.add(3 * k + j) * c[3];
        s += *r.add(4 * k + j) * c[4];
        s += *r.add(5 * k + j) * c[5];
        s += *r.add(6 * k + j) * c[6];
        s += *r.add(7 * k + j) * c[7];
        *op.add(j) = s;
        j += 1;
    }
}

/// Single-row vectorised saxpy with the scalar arm's zero-skip, used for
/// the < 8-row remainder (shared by vector and multi-pair forms).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn saxpy_row1_avx2(r: *const f32, k: usize, vi: f32, op: *mut f32) {
    let c = _mm256_set1_ps(vi);
    let mut j = 0;
    while j + 8 <= k {
        let o = _mm256_fmadd_ps(_mm256_loadu_ps(r.add(j)), c, _mm256_loadu_ps(op.add(j)));
        _mm256_storeu_ps(op.add(j), o);
        j += 8;
    }
    while j < k {
        *op.add(j) += *r.add(j) * vi;
        j += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn saxpy_rows_avx2(a: &Mat, v: &[f32], lo: usize, hi: usize, out: &mut [f32]) {
    let k = a.cols();
    let data = a.data().as_ptr();
    let op = out.as_mut_ptr();
    let mut i = lo;
    while i + 8 <= hi {
        saxpy_block8_avx2(data.add(i * k), k, &v[i..i + 8], op);
        i += 8;
    }
    while i < hi {
        let vi = v[i];
        if vi != 0.0 {
            saxpy_row1_avx2(data.add(i * k), k, vi, op);
        }
        i += 1;
    }
}

/// Multi-pair [`saxpy_rows`]: accumulate
/// `outs.row(p) += a[rows]^T @ us.row(p)[rows]` for every pair row,
/// streaming each row block of `a` once for all pairs. Per pair the
/// block decomposition and arithmetic are exactly the vector kernel's on
/// the same arm, so each output row is bitwise identical to it.
pub(crate) fn saxpy_rows_multi(
    level: SimdLevel,
    a: &Mat,
    us: &Mat,
    rows: Range<usize>,
    outs: &mut Mat,
) {
    match level {
        SimdLevel::Scalar => saxpy_rows_multi_scalar(a, us, rows, outs),
        SimdLevel::Avx2Fma => saxpy_rows_multi_avx2_call(a, us, rows, outs),
    }
}

/// The portable arm, verbatim from the pre-SIMD `ops.rs`.
fn saxpy_rows_multi_scalar(a: &Mat, us: &Mat, rows: Range<usize>, outs: &mut Mat) {
    let (lo, hi) = (rows.start, rows.end);
    let k = a.cols();
    let b = us.rows();
    let data = a.data();
    let mut i = lo;
    while i + 4 <= hi {
        let r0 = &data[i * k..(i + 1) * k];
        let r1 = &data[(i + 1) * k..(i + 2) * k];
        let r2 = &data[(i + 2) * k..(i + 3) * k];
        let r3 = &data[(i + 3) * k..(i + 4) * k];
        for p in 0..b {
            let (v0, v1, v2, v3) = (us[(p, i)], us[(p, i + 1)], us[(p, i + 2)], us[(p, i + 3)]);
            let out = outs.row_mut(p);
            for j in 0..k {
                out[j] += r0[j] * v0 + r1[j] * v1 + r2[j] * v2 + r3[j] * v3;
            }
        }
        i += 4;
    }
    while i < hi {
        for p in 0..b {
            let vi = us[(p, i)];
            if vi != 0.0 {
                let row = a.row(i);
                for (o, &r) in outs.row_mut(p).iter_mut().zip(row) {
                    *o += r * vi;
                }
            }
        }
        i += 1;
    }
}

#[cfg(target_arch = "x86_64")]
fn saxpy_rows_multi_avx2_call(a: &Mat, us: &Mat, rows: Range<usize>, outs: &mut Mat) {
    // SAFETY: `Avx2Fma` levels are sanitised at the public entry points.
    unsafe { saxpy_rows_multi_avx2(a, us, rows.start, rows.end, outs) }
}

#[cfg(not(target_arch = "x86_64"))]
fn saxpy_rows_multi_avx2_call(a: &Mat, us: &Mat, rows: Range<usize>, outs: &mut Mat) {
    saxpy_rows_multi_scalar(a, us, rows, outs)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn saxpy_rows_multi_avx2(a: &Mat, us: &Mat, lo: usize, hi: usize, outs: &mut Mat) {
    let k = a.cols();
    let b = us.rows();
    let data = a.data().as_ptr();
    let mut i = lo;
    while i + 8 <= hi {
        let r = data.add(i * k);
        for p in 0..b {
            let coeffs = &us.row(p)[i..i + 8];
            saxpy_block8_avx2(r, k, coeffs, outs.row_mut(p).as_mut_ptr());
        }
        i += 8;
    }
    while i < hi {
        for p in 0..b {
            let vi = us.row(p)[i];
            if vi != 0.0 {
                saxpy_row1_avx2(data.add(i * k), k, vi, outs.row_mut(p).as_mut_ptr());
            }
        }
        i += 1;
    }
}

// ---------------------------------------------------------------------
// lse_row / lse_accum_rows: the log-domain reductions.
// ---------------------------------------------------------------------

/// One row of the log-space matvec:
/// `logsumexp_j(alpha * row[j] + t[j])`, two passes (max, then sum of
/// shifted exps) entirely in f64. Returns `-inf` when every term is
/// `-inf`. The AVX2 arm evaluates the shifted exponentials with
/// [`vexp::exp4`] (≤ 2 ulp — see `special/vexp.rs`) on 4 lanes with a
/// fixed lane-reduction order.
#[inline]
pub(crate) fn lse_row(level: SimdLevel, row: &[f32], alpha: f64, t: &[f64]) -> f64 {
    debug_assert_eq!(row.len(), t.len());
    match level {
        SimdLevel::Scalar => lse_row_scalar(row, alpha, t),
        SimdLevel::Avx2Fma => lse_row_avx2_call(row, alpha, t),
    }
}

/// The portable arm, verbatim from the pre-SIMD `ops.rs`.
fn lse_row_scalar(row: &[f32], alpha: f64, t: &[f64]) -> f64 {
    let mut m = f64::NEG_INFINITY;
    for (&aij, &tj) in row.iter().zip(t) {
        let v = alpha * aij as f64 + tj;
        if v > m {
            m = v;
        }
    }
    if !m.is_finite() {
        return m;
    }
    let mut s = 0.0f64;
    for (&aij, &tj) in row.iter().zip(t) {
        s += (alpha * aij as f64 + tj - m).exp();
    }
    m + s.ln()
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn lse_row_avx2_call(row: &[f32], alpha: f64, t: &[f64]) -> f64 {
    // SAFETY: `Avx2Fma` levels are sanitised at the public entry points.
    unsafe { lse_row_avx2(row, alpha, t) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn lse_row_avx2_call(row: &[f32], alpha: f64, t: &[f64]) -> f64 {
    lse_row_scalar(row, alpha, t)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn lse_row_avx2(row: &[f32], alpha: f64, t: &[f64]) -> f64 {
    let k = row.len();
    let k4 = k - k % 4;
    let rp = row.as_ptr();
    let tp = t.as_ptr();
    let av = _mm256_set1_pd(alpha);
    // Pass 1: max of alpha*a + t; both passes compute the terms with the
    // same fused multiply-add, so the shift in pass 2 is never positive.
    let mut m = f64::NEG_INFINITY;
    let mut j = 0;
    if k4 > 0 {
        let mut m4 = _mm256_set1_pd(f64::NEG_INFINITY);
        while j < k4 {
            let r4 = _mm256_cvtps_pd(_mm_loadu_ps(rp.add(j)));
            let val = _mm256_fmadd_pd(av, r4, _mm256_loadu_pd(tp.add(j)));
            m4 = _mm256_max_pd(m4, val);
            j += 4;
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), m4);
        for &l in &lanes {
            if l > m {
                m = l;
            }
        }
    }
    while j < k {
        let val = alpha * (*rp.add(j) as f64) + *tp.add(j);
        if val > m {
            m = val;
        }
        j += 1;
    }
    if !m.is_finite() {
        return m;
    }
    // Pass 2: sum of shifted exponentials, 4-lane partials reduced in
    // fixed lane order, remainder through libm (index-determined, so
    // still bitwise reproducible).
    let mv = _mm256_set1_pd(m);
    let mut s4 = _mm256_setzero_pd();
    j = 0;
    while j < k4 {
        let r4 = _mm256_cvtps_pd(_mm_loadu_ps(rp.add(j)));
        let val = _mm256_fmadd_pd(av, r4, _mm256_loadu_pd(tp.add(j)));
        s4 = _mm256_add_pd(s4, vexp::exp4(_mm256_sub_pd(val, mv)));
        j += 4;
    }
    let mut lanes = [0.0f64; 4];
    _mm256_storeu_pd(lanes.as_mut_ptr(), s4);
    let mut s = ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3];
    while j < k {
        s += (alpha * (*rp.add(j) as f64) + *tp.add(j) - m).exp();
        j += 1;
    }
    m + s.ln()
}

/// Per-column (max, sum-of-shifted-exps) accumulation over `rows`, the
/// building block both transposed logsumexp variants share. `mx`/`sum`
/// must come in as `(-inf, 0.0)` per column (or carry a prior chunk's
/// partial on the same arm).
pub(crate) fn lse_accum_rows(
    level: SimdLevel,
    a: &Mat,
    alpha: f64,
    u: &[f64],
    rows: Range<usize>,
    mx: &mut [f64],
    sum: &mut [f64],
) {
    match level {
        SimdLevel::Scalar => lse_accum_rows_scalar(a, alpha, u, rows, mx, sum),
        SimdLevel::Avx2Fma => lse_accum_rows_avx2_call(a, alpha, u, rows, mx, sum),
    }
}

/// The portable arm, verbatim from the pre-SIMD `ops.rs`.
fn lse_accum_rows_scalar(
    a: &Mat,
    alpha: f64,
    u: &[f64],
    rows: Range<usize>,
    mx: &mut [f64],
    sum: &mut [f64],
) {
    // Pass 1: per-column max over the row range.
    for i in rows.clone() {
        let ui = u[i];
        for (m, &aij) in mx.iter_mut().zip(a.row(i)) {
            let v = alpha * aij as f64 + ui;
            if v > *m {
                *m = v;
            }
        }
    }
    // Pass 2: shifted exponentials (columns whose max is -inf stay 0).
    for i in rows {
        let ui = u[i];
        for ((s, &m), &aij) in sum.iter_mut().zip(mx.iter()).zip(a.row(i)) {
            if m.is_finite() {
                *s += (alpha * aij as f64 + ui - m).exp();
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
fn lse_accum_rows_avx2_call(
    a: &Mat,
    alpha: f64,
    u: &[f64],
    rows: Range<usize>,
    mx: &mut [f64],
    sum: &mut [f64],
) {
    // SAFETY: `Avx2Fma` levels are sanitised at the public entry points.
    unsafe { lse_accum_rows_avx2(a, alpha, u, rows.start, rows.end, mx, sum) }
}

#[cfg(not(target_arch = "x86_64"))]
fn lse_accum_rows_avx2_call(
    a: &Mat,
    alpha: f64,
    u: &[f64],
    rows: Range<usize>,
    mx: &mut [f64],
    sum: &mut [f64],
) {
    lse_accum_rows_scalar(a, alpha, u, rows, mx, sum)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn lse_accum_rows_avx2(
    a: &Mat,
    alpha: f64,
    u: &[f64],
    lo: usize,
    hi: usize,
    mx: &mut [f64],
    sum: &mut [f64],
) {
    let k = a.cols();
    let k4 = k - k % 4;
    let data = a.data().as_ptr();
    let av = _mm256_set1_pd(alpha);
    let mp = mx.as_mut_ptr();
    // Pass 1: per-column max, 4 columns per step; the same FMA term is
    // recomputed in pass 2 so shifts stay <= 0.
    for i in lo..hi {
        let ui = _mm256_set1_pd(u[i]);
        let rp = data.add(i * k);
        let mut j = 0;
        while j < k4 {
            let val = _mm256_fmadd_pd(av, _mm256_cvtps_pd(_mm_loadu_ps(rp.add(j))), ui);
            _mm256_storeu_pd(mp.add(j), _mm256_max_pd(_mm256_loadu_pd(mp.add(j)), val));
            j += 4;
        }
        while j < k {
            let val = alpha * (*rp.add(j) as f64) + u[i];
            if val > *mp.add(j) {
                *mp.add(j) = val;
            }
            j += 1;
        }
    }
    // Pass 2: shifted exponentials via exp4; columns whose max is -inf
    // are masked to 0 (the scalar arm's `is_finite` guard — the max can
    // never be +inf or NaN here, terms are finite or -inf).
    let sp = sum.as_mut_ptr();
    let ninf = _mm256_set1_pd(f64::NEG_INFINITY);
    for i in lo..hi {
        let ui = _mm256_set1_pd(u[i]);
        let rp = data.add(i * k);
        let mut j = 0;
        while j < k4 {
            let m4 = _mm256_loadu_pd(mp.add(j));
            let val = _mm256_fmadd_pd(av, _mm256_cvtps_pd(_mm_loadu_ps(rp.add(j))), ui);
            let e = vexp::exp4(_mm256_sub_pd(val, m4));
            let finite = _mm256_cmp_pd::<_CMP_GT_OQ>(m4, ninf);
            let e = _mm256_and_pd(e, finite);
            _mm256_storeu_pd(sp.add(j), _mm256_add_pd(_mm256_loadu_pd(sp.add(j)), e));
            j += 4;
        }
        while j < k {
            if (*mp.add(j)).is_finite() {
                *sp.add(j) += (alpha * (*rp.add(j) as f64) + u[i] - *mp.add(j)).exp();
            }
            j += 1;
        }
    }
}

/// The transposed logsumexp's finishing pass:
/// `out[j] = mx[j] + ln(sum[j])` per column, `-inf` max columns passed
/// through unchanged. The AVX2 arm evaluates the logarithm with the
/// 4-lane `ln4` polynomial (`special/vexp.rs`, ≤ 2 ulp); the scalar arm
/// is libm, verbatim from the pre-SIMD `ops.rs`. (The pooled variants'
/// cross-chunk merges stay scalar on every arm — they are the
/// thread-invariance anchor and run once per k, off the per-row path.)
pub(crate) fn lse_finish(level: SimdLevel, mx: &[f64], sum: &[f64], out: &mut [f64]) {
    debug_assert_eq!(mx.len(), out.len());
    debug_assert_eq!(sum.len(), out.len());
    match level {
        SimdLevel::Scalar => lse_finish_scalar(mx, sum, out),
        SimdLevel::Avx2Fma => lse_finish_avx2_call(mx, sum, out),
    }
}

fn lse_finish_scalar(mx: &[f64], sum: &[f64], out: &mut [f64]) {
    for ((o, &m), &s) in out.iter_mut().zip(mx).zip(sum) {
        *o = if m.is_finite() { m + s.ln() } else { m };
    }
}

#[cfg(target_arch = "x86_64")]
fn lse_finish_avx2_call(mx: &[f64], sum: &[f64], out: &mut [f64]) {
    // SAFETY: `Avx2Fma` levels are sanitised at the public entry points.
    unsafe { lse_finish_avx2(mx, sum, out) }
}

#[cfg(not(target_arch = "x86_64"))]
fn lse_finish_avx2_call(mx: &[f64], sum: &[f64], out: &mut [f64]) {
    lse_finish_scalar(mx, sum, out)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn lse_finish_avx2(mx: &[f64], sum: &[f64], out: &mut [f64]) {
    let k = out.len();
    let k4 = k - k % 4;
    let mp = mx.as_ptr();
    let sp = sum.as_ptr();
    let op = out.as_mut_ptr();
    let ninf = _mm256_set1_pd(f64::NEG_INFINITY);
    let mut j = 0;
    while j < k4 {
        let m4 = _mm256_loadu_pd(mp.add(j));
        let res = _mm256_add_pd(m4, vexp::ln4(_mm256_loadu_pd(sp.add(j))));
        // Columns whose max is -inf carry m through unchanged (the max
        // can never be +inf or NaN here — terms are finite or -inf).
        let finite = _mm256_cmp_pd::<_CMP_GT_OQ>(m4, ninf);
        _mm256_storeu_pd(op.add(j), _mm256_blendv_pd(m4, res, finite));
        j += 4;
    }
    while j < k {
        let m = *mp.add(j);
        *op.add(j) = if m.is_finite() { m + (*sp.add(j)).ln() } else { m };
        j += 1;
    }
}

// ---------------------------------------------------------------------
// dot_f32: the plain feature-evaluation dot.
// ---------------------------------------------------------------------

/// Plain f32 dot product — the inner loop of the feature maps'
/// `eval_into` (anchor · point per feature). The scalar arm is the
/// sequential f32 sum the feature maps always used; the AVX2 arm runs an
/// 8-lane FMA accumulator with a fixed lane-order reduction. The level
/// is sanitised here (this is a public entry point, unlike the
/// `pub(crate)` kernels above whose callers sanitise at the `*_at`
/// boundary) — the check is one cached-feature lookup against a dot.
#[inline]
pub fn dot_f32(level: SimdLevel, x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len());
    match level.sanitize() {
        SimdLevel::Scalar => dot_f32_scalar(x, y),
        SimdLevel::Avx2Fma => dot_f32_avx2_call(x, y),
    }
}

fn dot_f32_scalar(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(&a, &b)| a * b).sum::<f32>()
}

#[cfg(target_arch = "x86_64")]
#[inline]
fn dot_f32_avx2_call(x: &[f32], y: &[f32]) -> f32 {
    // SAFETY: callers pass sanitised levels (see `dot_f32` docs).
    unsafe { dot_f32_avx2(x, y) }
}

#[cfg(not(target_arch = "x86_64"))]
#[inline]
fn dot_f32_avx2_call(x: &[f32], y: &[f32]) -> f32 {
    dot_f32_scalar(x, y)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,fma")]
unsafe fn dot_f32_avx2(x: &[f32], y: &[f32]) -> f32 {
    let n = x.len();
    let n8 = n - n % 8;
    let xp = x.as_ptr();
    let yp = y.as_ptr();
    let mut acc = _mm256_setzero_ps();
    let mut i = 0;
    while i < n8 {
        acc = _mm256_fmadd_ps(_mm256_loadu_ps(xp.add(i)), _mm256_loadu_ps(yp.add(i)), acc);
        i += 8;
    }
    let mut lanes = [0.0f32; 8];
    _mm256_storeu_ps(lanes.as_mut_ptr(), acc);
    let mut s = 0.0f32;
    for &l in &lanes {
        s += l;
    }
    while i < n {
        s += *xp.add(i) * *yp.add(i);
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn level_label_and_sanitize() {
        assert_eq!(SimdLevel::Scalar.label(), "scalar");
        assert_eq!(SimdLevel::Avx2Fma.label(), "avx2+fma");
        assert_eq!(SimdLevel::Scalar.sanitize(), SimdLevel::Scalar);
        if !avx2_available() {
            assert_eq!(SimdLevel::Avx2Fma.sanitize(), SimdLevel::Scalar);
        } else {
            assert_eq!(SimdLevel::Avx2Fma.sanitize(), SimdLevel::Avx2Fma);
        }
        // active_level never reports an arm the machine cannot run.
        assert_eq!(active_level(), active_level().sanitize());
    }

    #[test]
    fn row_dot_arms_agree_at_lane_boundaries() {
        let mut rng = Rng::seed_from(1);
        for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 130, 200] {
            let row = rand_vec(&mut rng, n);
            let v = rand_vec(&mut rng, n);
            let scalar = row_dot(SimdLevel::Scalar, &row, &v);
            let simd = row_dot(SimdLevel::Avx2Fma.sanitize(), &row, &v);
            // Summation error scales with the absolute term sum, not the
            // (possibly cancelling) signed result — normalise by it.
            let scale: f64 =
                row.iter().zip(&v).map(|(&a, &b)| ((a * b).abs()) as f64).sum::<f64>().max(1.0);
            assert!(
                ((scalar as f64) - (simd as f64)).abs() / scale <= 1e-5,
                "n={n}: scalar {scalar} vs simd {simd}"
            );
        }
    }

    #[test]
    fn saxpy_arms_agree_and_handle_remainders() {
        let mut rng = Rng::seed_from(2);
        for (n, k) in [(0usize, 5usize), (1, 3), (7, 9), (8, 8), (9, 17), (23, 33), (40, 1)] {
            let a = Mat::from_fn(n, k, |_, _| rng.normal_f32());
            let v = rand_vec(&mut rng, n);
            let mut scalar = vec![0.0f32; k];
            saxpy_rows(SimdLevel::Scalar, &a, &v, 0..n, &mut scalar);
            let mut simd = vec![0.0f32; k];
            saxpy_rows(SimdLevel::Avx2Fma.sanitize(), &a, &v, 0..n, &mut simd);
            for j in 0..k {
                let scale: f64 = (0..n)
                    .map(|i| ((a[(i, j)] * v[i]).abs()) as f64)
                    .sum::<f64>()
                    .max(1.0);
                assert!(
                    ((scalar[j] as f64) - (simd[j] as f64)).abs() / scale <= 1e-5,
                    "({n},{k}) col {j}"
                );
            }
        }
    }

    #[test]
    fn saxpy_multi_is_bitwise_vector_kernel_per_pair_on_both_arms() {
        let mut rng = Rng::seed_from(3);
        for level in [SimdLevel::Scalar, SimdLevel::Avx2Fma.sanitize()] {
            for (n, k, b) in [(9usize, 7usize, 3usize), (16, 8, 2), (17, 12, 4)] {
                let a = Mat::from_fn(n, k, |_, _| rng.normal_f32());
                let us = Mat::from_fn(b, n, |_, _| rng.normal_f32());
                let mut fused = Mat::zeros(b, k);
                saxpy_rows_multi(level, &a, &us, 0..n, &mut fused);
                for p in 0..b {
                    let mut want = vec![0.0f32; k];
                    saxpy_rows(level, &a, us.row(p), 0..n, &mut want);
                    for j in 0..k {
                        assert_eq!(
                            fused[(p, j)].to_bits(),
                            want[j].to_bits(),
                            "{} ({n},{k},{b}) pair {p} col {j}",
                            level.label()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn lse_row_arms_agree() {
        let mut rng = Rng::seed_from(4);
        for k in [0usize, 1, 3, 4, 5, 7, 8, 9, 16, 17, 33, 100] {
            let row = rand_vec(&mut rng, k);
            let t: Vec<f64> = (0..k).map(|_| rng.normal_f32() as f64 * 5.0).collect();
            let alpha = -1.7;
            let scalar = lse_row(SimdLevel::Scalar, &row, alpha, &t);
            let simd = lse_row(SimdLevel::Avx2Fma.sanitize(), &row, alpha, &t);
            if k == 0 {
                assert_eq!(scalar, f64::NEG_INFINITY);
                assert_eq!(simd, f64::NEG_INFINITY);
                continue;
            }
            let scale = scalar.abs().max(1.0);
            assert!((scalar - simd).abs() / scale <= 1e-12, "k={k}: {scalar} vs {simd}");
        }
    }

    #[test]
    fn lse_row_neg_inf_inputs_on_both_arms() {
        let row = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let t = [f64::NEG_INFINITY; 5];
        for level in [SimdLevel::Scalar, SimdLevel::Avx2Fma.sanitize()] {
            assert_eq!(lse_row(level, &row, 1.0, &t), f64::NEG_INFINITY, "{}", level.label());
            // A single finite term dominates regardless of -inf lanes.
            let mut t1 = t;
            t1[3] = 2.0;
            let got = lse_row(level, &row, 1.0, &t1);
            assert!((got - 6.0).abs() < 1e-12, "{}: {got}", level.label());
        }
    }

    #[test]
    fn lse_accum_arms_agree() {
        let mut rng = Rng::seed_from(5);
        for (n, k) in [(1usize, 1usize), (5, 4), (9, 7), (16, 16), (33, 13)] {
            let a = Mat::from_fn(n, k, |_, _| rng.normal_f32());
            let u: Vec<f64> = (0..n).map(|_| rng.normal_f32() as f64 * 5.0).collect();
            let alpha = 0.8;
            let mut mx_s = vec![f64::NEG_INFINITY; k];
            let mut sum_s = vec![0.0f64; k];
            lse_accum_rows(SimdLevel::Scalar, &a, alpha, &u, 0..n, &mut mx_s, &mut sum_s);
            let mut mx_v = vec![f64::NEG_INFINITY; k];
            let mut sum_v = vec![0.0f64; k];
            lse_accum_rows(
                SimdLevel::Avx2Fma.sanitize(),
                &a,
                alpha,
                &u,
                0..n,
                &mut mx_v,
                &mut sum_v,
            );
            for j in 0..k {
                assert!((mx_s[j] - mx_v[j]).abs() <= 1e-12, "({n},{k}) max col {j}");
                assert!(
                    (sum_s[j] - sum_v[j]).abs() / sum_s[j].abs().max(1.0) <= 1e-12,
                    "({n},{k}) sum col {j}"
                );
            }
        }
    }

    #[test]
    fn dot_f32_arms_agree() {
        let mut rng = Rng::seed_from(6);
        for n in [0usize, 1, 7, 8, 9, 16, 31, 64, 100] {
            let x = rand_vec(&mut rng, n);
            let y = rand_vec(&mut rng, n);
            let scalar = dot_f32(SimdLevel::Scalar, &x, &y);
            let simd = dot_f32(SimdLevel::Avx2Fma.sanitize(), &x, &y);
            let scale: f64 =
                x.iter().zip(&y).map(|(&a, &b)| ((a * b).abs()) as f64).sum::<f64>().max(1.0);
            assert!(
                ((scalar as f64) - (simd as f64)).abs() / scale <= 1e-5,
                "n={n}: {scalar} vs {simd}"
            );
        }
    }
}
