//! Dense linear algebra substrate.
//!
//! The offline crate set has no BLAS/ndarray, so the whole stack sits on
//! this module: a row-major [`Mat`] plus the blocked matvec / matmul
//! routines that are the per-iteration cost of every Sinkhorn variant.
//! The hot paths (`matvec`, `matvec_t`, `apply` in `kernels/`) are written
//! to be allocation-free given caller-provided output buffers, and since
//! the SIMD core landed they run on **runtime-dispatched kernels**
//! ([`simd`]): an AVX2+FMA arm with explicit intrinsics where the CPU
//! supports it, and the original scalar code as the portable fallback
//! (`LINEAR_SINKHORN_SIMD=scalar` forces it; EXPERIMENTS.md §Perf,
//! "SIMD core"). Every kernel also has an `*_at` twin taking an explicit
//! [`SimdLevel`] for tests and benches. The `_pooled` variants run the
//! same kernels row-chunked over a [`crate::runtime::pool::Pool`] with
//! thread-count-independent results *on each arm* (EXPERIMENTS.md
//! §Parallel scaling).

mod mat;
mod ops;
pub mod simd;

pub use mat::Mat;
pub use ops::{
    axpy, dot, l1_diff, l1_norm, logsumexp, lse_matmat_into, lse_matmat_into_pooled,
    lse_matmat_t_into, lse_matmat_t_into_pooled, lse_matvec_into, lse_matvec_into_pooled,
    lse_matvec_t_into, lse_matvec_t_into_pooled, matmat_into, matmat_into_pooled,
    matmat_t_into, matmat_t_into_pooled, matmul, matvec, matvec_into, matvec_into_pooled,
    matvec_t, matvec_t_into, matvec_t_into_pooled, max_abs_diff, scale, softmax_inplace, sum,
};
pub use ops::{
    lse_matmat_into_at, lse_matmat_into_pooled_at, lse_matmat_t_into_at,
    lse_matmat_t_into_pooled_at, lse_matvec_into_at, lse_matvec_into_pooled_at,
    lse_matvec_t_into_at, lse_matvec_t_into_pooled_at, matmat_into_at, matmat_into_pooled_at,
    matmat_t_into_at, matmat_t_into_pooled_at, matvec_into_at, matvec_into_pooled_at,
    matvec_t_into_at, matvec_t_into_pooled_at,
};
pub use simd::SimdLevel;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    fn rand_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
        Mat::from_fn(rows, cols, |_, _| rng.normal_f32())
    }

    #[test]
    fn matvec_matches_naive() {
        let mut rng = Rng::seed_from(1);
        for &(m, k) in &[(1usize, 1usize), (3, 7), (17, 33), (64, 64), (130, 67)] {
            let a = rand_mat(&mut rng, m, k);
            let v: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let got = matvec(&a, &v);
            for i in 0..m {
                let want: f32 = (0..k).map(|j| a[(i, j)] * v[j]).sum();
                assert!((got[i] - want).abs() <= 1e-4 * want.abs().max(1.0), "({m},{k}) row {i}");
            }
        }
    }

    #[test]
    fn matvec_t_matches_naive() {
        let mut rng = Rng::seed_from(2);
        for &(m, k) in &[(1usize, 1usize), (5, 3), (33, 17), (128, 96)] {
            let a = rand_mat(&mut rng, m, k);
            let v: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            let got = matvec_t(&a, &v);
            for j in 0..k {
                let want: f32 = (0..m).map(|i| a[(i, j)] * v[i]).sum();
                assert!((got[j] - want).abs() <= 1e-3 * want.abs().max(1.0));
            }
        }
    }

    #[test]
    fn matvec_adjoint_identity() {
        // <u, A v> == <A^T u, v> — the identity the factored Sinkhorn
        // update relies on.
        let mut rng = Rng::seed_from(3);
        let a = rand_mat(&mut rng, 23, 31);
        let u: Vec<f32> = (0..23).map(|_| rng.normal_f32()).collect();
        let v: Vec<f32> = (0..31).map(|_| rng.normal_f32()).collect();
        let lhs = dot(&u, &matvec(&a, &v));
        let rhs = dot(&matvec_t(&a, &u), &v);
        assert!((lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0));
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::seed_from(4);
        let a = rand_mat(&mut rng, 9, 13);
        let b = rand_mat(&mut rng, 13, 11);
        let c = matmul(&a, &b);
        for i in 0..9 {
            for j in 0..11 {
                let want: f32 = (0..13).map(|k| a[(i, k)] * b[(k, j)]).sum();
                assert!((c[(i, j)] - want).abs() < 1e-4 * want.abs().max(1.0));
            }
        }
    }

    #[test]
    fn mat_transpose_roundtrip() {
        let mut rng = Rng::seed_from(5);
        let a = rand_mat(&mut rng, 7, 12);
        let att = a.transpose().transpose();
        assert_eq!(a.rows(), att.rows());
        assert!(max_abs_diff(a.data(), att.data()) == 0.0);
    }

    fn naive_lse_matvec(a: &Mat, alpha: f64, t: &[f64]) -> Vec<f64> {
        (0..a.rows())
            .map(|i| {
                let terms: Vec<f64> =
                    a.row(i).iter().zip(t).map(|(&x, &tj)| alpha * x as f64 + tj).collect();
                let m = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                if !m.is_finite() {
                    return m;
                }
                m + terms.iter().map(|&v| (v - m).exp()).sum::<f64>().ln()
            })
            .collect()
    }

    #[test]
    fn lse_matvec_matches_naive() {
        let mut rng = Rng::seed_from(11);
        for &(m, k) in &[(1usize, 1usize), (3, 7), (40, 33), (130, 5)] {
            let a = rand_mat(&mut rng, m, k);
            let t: Vec<f64> = (0..k).map(|_| rng.normal_f32() as f64 * 3.0).collect();
            let alpha = -2.0;
            let mut got = vec![0.0f64; m];
            lse_matvec_into(&a, alpha, &t, &mut got);
            let want = naive_lse_matvec(&a, alpha, &t);
            for i in 0..m {
                assert!((got[i] - want[i]).abs() < 1e-12, "({m},{k}) row {i}");
            }
        }
    }

    #[test]
    fn lse_matvec_t_matches_naive_via_transpose() {
        let mut rng = Rng::seed_from(12);
        for &(m, k) in &[(1usize, 1usize), (5, 3), (64, 17), (200, 9)] {
            let a = rand_mat(&mut rng, m, k);
            let u: Vec<f64> = (0..m).map(|_| rng.normal_f32() as f64 * 3.0).collect();
            let alpha = -0.5;
            let mut got = vec![0.0f64; k];
            lse_matvec_t_into(&a, alpha, &u, &mut got);
            let want = naive_lse_matvec(&a.transpose(), alpha, &u);
            for j in 0..k {
                assert!((got[j] - want[j]).abs() < 1e-12, "({m},{k}) col {j}");
            }
        }
    }

    #[test]
    fn lse_matvec_survives_extreme_log_inputs() {
        // Inputs around ±1e4 (the alpha/eps scale of small-eps log-domain
        // Sinkhorn): plain exp would over/underflow, the shifted form
        // stays finite and exact in the dominant term.
        let a = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        let t = vec![-2e4f64, -1e4];
        let mut out = vec![0.0f64; 2];
        lse_matvec_into(&a, 1.0, &t, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
        assert!((out[0] - (-1e4 + 2.0)).abs() < 1e-6);
        // All-(-inf) rows report -inf rather than NaN.
        let mut out1 = vec![0.0f64; 2];
        lse_matvec_into(&a, 1.0, &[f64::NEG_INFINITY; 2], &mut out1);
        assert!(out1.iter().all(|x| *x == f64::NEG_INFINITY));
        let mut out2 = vec![0.0f64; 2];
        lse_matvec_t_into(&a, 1.0, &[f64::NEG_INFINITY; 2], &mut out2);
        assert!(out2.iter().all(|x| *x == f64::NEG_INFINITY));
    }

    #[test]
    fn matmat_rows_match_matvec() {
        // Every pair row of the fused forms is bitwise the vector kernel.
        let mut rng = Rng::seed_from(21);
        for &(n, k, b) in &[(1usize, 1usize, 1usize), (7, 3, 2), (150, 33, 5)] {
            let a = rand_mat(&mut rng, n, k);
            let vs = rand_mat(&mut rng, b, k);
            let mut fused = Mat::zeros(b, n);
            matmat_into(&a, &vs, &mut fused);
            let us = rand_mat(&mut rng, b, n);
            let mut fused_t = Mat::zeros(b, k);
            matmat_t_into(&a, &us, &mut fused_t);
            for p in 0..b {
                let want = matvec(&a, vs.row(p));
                assert_eq!(fused.row(p), &want[..], "({n},{k},{b}) pair {p}");
                let want_t = matvec_t(&a, us.row(p));
                assert_eq!(fused_t.row(p), &want_t[..], "({n},{k},{b}) pair {p} transposed");
            }
        }
    }

    #[test]
    fn lse_matmat_rows_match_lse_matvec() {
        let mut rng = Rng::seed_from(22);
        for &(n, k, b) in &[(1usize, 1usize, 1usize), (9, 4, 3), (120, 17, 4)] {
            let a = rand_mat(&mut rng, n, k);
            let alpha = -1.5;
            let ts: Vec<Vec<f64>> = (0..b)
                .map(|_| (0..k).map(|_| rng.normal_f32() as f64 * 5.0).collect())
                .collect();
            let mut outs: Vec<Vec<f64>> = (0..b).map(|_| vec![0.0f64; n]).collect();
            lse_matmat_into(&a, alpha, &ts, &mut outs);
            let us: Vec<Vec<f64>> = (0..b)
                .map(|_| (0..n).map(|_| rng.normal_f32() as f64 * 5.0).collect())
                .collect();
            let mut outs_t: Vec<Vec<f64>> = (0..b).map(|_| vec![0.0f64; k]).collect();
            lse_matmat_t_into(&a, alpha, &us, &mut outs_t);
            for p in 0..b {
                let mut want = vec![0.0f64; n];
                lse_matvec_into(&a, alpha, &ts[p], &mut want);
                assert_eq!(outs[p], want, "({n},{k},{b}) pair {p}");
                let mut want_t = vec![0.0f64; k];
                lse_matvec_t_into(&a, alpha, &us[p], &mut want_t);
                assert_eq!(outs_t[p], want_t, "({n},{k},{b}) pair {p} transposed");
            }
        }
    }

    #[test]
    fn logsumexp_is_shift_invariant() {
        let xs = [1.0f32, 2.0, 3.0, -1.0];
        let shifted: Vec<f32> = xs.iter().map(|x| x + 100.0).collect();
        let a = logsumexp(&xs);
        let b = logsumexp(&shifted);
        assert!((b - (a + 100.0)).abs() < 1e-4);
    }

    #[test]
    fn logsumexp_handles_extremes() {
        assert!(logsumexp(&[-1e30f32, -1e30]).is_finite());
        let one = logsumexp(&[0.0f32]);
        assert!((one - 0.0).abs() < 1e-7);
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![0.5f32, -2.0, 7.0, 0.0];
        softmax_inplace(&mut xs, 1.0);
        let s: f32 = xs.iter().sum();
        assert!((s - 1.0).abs() < 1e-5);
        assert!(xs.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn softmax_temperature_sharpens() {
        let mut cold = vec![1.0f32, 2.0, 3.0];
        let mut hot = cold.clone();
        softmax_inplace(&mut cold, 1.0);
        softmax_inplace(&mut hot, 100.0);
        assert!(hot[2] > cold[2]); // higher temperature (paper's T=1000 sense) sharpens peaks
    }

    #[test]
    fn mat_from_rows_and_indexing() {
        let m = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0]]);
        assert_eq!(m[(0, 1)], 2.0);
        assert_eq!(m.row(1), &[3.0, 4.0]);
        assert_eq!(m.col_copy(0), vec![1.0, 3.0]);
    }

    #[test]
    #[should_panic]
    fn mat_shape_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let v = vec![1.0f32; 5];
        let _ = matvec(&a, &v);
    }
}

#[cfg(test)]
mod perf_probe {
    use super::*;
    use crate::rng::Rng;
    use std::time::Instant;

    #[test]
    #[ignore]
    fn matvec_throughput() {
        let mut rng = Rng::seed_from(0);
        for &(m, k) in &[(4000usize, 400usize), (400, 4000), (2000, 2000)] {
            let a = Mat::from_fn(m, k, |_, _| rng.normal_f32());
            let v: Vec<f32> = (0..k).map(|_| rng.normal_f32()).collect();
            let w: Vec<f32> = (0..m).map(|_| rng.normal_f32()).collect();
            let mut out = vec![0.0f32; m];
            let mut out_t = vec![0.0f32; k];
            let reps = 200;
            let t = Instant::now();
            for _ in 0..reps { matvec_into(&a, &v, &mut out); }
            let mv = t.elapsed().as_secs_f64() / reps as f64;
            let t = Instant::now();
            for _ in 0..reps { matvec_t_into(&a, &w, &mut out_t); }
            let mvt = t.elapsed().as_secs_f64() / reps as f64;
            let flops = 2.0 * m as f64 * k as f64;
            println!("{m}x{k}: matvec {:.0}us ({:.1} GF/s)  matvec_t {:.0}us ({:.1} GF/s)",
                mv*1e6, flops/mv/1e9, mvt*1e6, flops/mvt/1e9);
        }
    }
}
