//! Vector/matrix kernels. The `matvec`/`matvec_t` pair is the entire
//! per-iteration cost of every Sinkhorn variant in this crate; since the
//! SIMD core landed, both run on runtime-dispatched kernels ([`super::simd`]):
//! an AVX2+FMA arm with explicit intrinsics where the CPU supports it and
//! the original scalar code as the portable fallback
//! (`LINEAR_SINKHORN_SIMD=scalar` forces it). The `_into` variants are
//! allocation-free for the coordinator's hot loop, and every public
//! kernel has an `_at` twin taking an explicit [`SimdLevel`] — the
//! entry points the scalar-vs-SIMD agreement tests and the
//! `simd_kernels` bench use to pin an arm.
//!
//! The `_pooled` variants run the same kernels row-chunked over a
//! [`Pool`]. They preserve the serial accuracy contract — see the
//! per-function docs — and their output never depends on the thread
//! count (EXPERIMENTS.md §Parallel scaling). One caveat to the
//! allocation-free rule: the pooled transposed matvec allocates its
//! per-chunk partial buffers when the row count exceeds one chunk
//! (1024 rows) — a few KB against a millisecond-scale apply.
//!
//! The `lse_matvec*` family is the log-domain counterpart: chunk-gridded
//! logsumexp reductions of `alpha * A + input` over rows/columns, in f64,
//! used by [`crate::kernels::LogKernelOp`] to run small-eps stabilised
//! Sinkhorn without materialising a kernel (EXPERIMENTS.md
//! §Stabilisation). On the AVX2 arm the per-entry `exp` runs through the
//! vectorised polynomial [`crate::special::vexp`] (≤ 2 ulp). The
//! transposed variants allocate per-column `(max, sumexp)` scratch —
//! O(k) against an O(nk) reduction.
//!
//! The `matmat*` / `lse_matmat*` families are the **column-blocked**
//! (multi-right-hand-side) forms of the same four kernels: B input
//! vectors are carried pair-major (one row of a [`Mat`] — or one
//! `Vec<f64>` — per vector) and every pass over `a` serves all B columns
//! at once, which is what makes the batched multi-pair Sinkhorn engine
//! ([`crate::sinkhorn::solve_batch`]) O(r·Σn) per fused apply with one
//! stream over the factors instead of B. Each column is computed with the
//! *same* per-row/per-chunk kernels as the vector variants (`row_dot`,
//! `saxpy_rows`, `lse_row`, `lse_accum_rows` in [`super::simd`]) on the
//! same fixed chunk grids, so column `k` of a fused apply is **bitwise
//! identical** to the corresponding vector apply at every pool size *and
//! on either dispatch arm* — the property the batched solver's
//! sequential-equivalence contract rests on
//! (`rust/tests/batched_equivalence.rs`).

use super::simd::{self, SimdLevel};
use super::Mat;
use crate::runtime::pool::Pool;

/// Rows per parallel task of [`matvec_into_pooled`]. Small enough to load-
/// balance ragged pools, large enough that task-queue traffic is noise.
const PAR_ROW_CHUNK: usize = 256;

/// Rows per partial accumulator of [`matvec_t_into_pooled`]. This is a
/// *fixed* grid — chunk boundaries never depend on the thread count — so
/// the chunked reduction is deterministic for any pool size.
const PAR_T_CHUNK: usize = 1024;

/// Rows per parallel task of [`lse_matvec_into_pooled`]. A logsumexp row
/// costs an f64 `exp` per entry — far denser than a fused multiply — so
/// smaller chunks than [`PAR_ROW_CHUNK`] still amortise dispatch.
const PAR_LSE_ROW_CHUNK: usize = 128;

/// Rows per partial of [`lse_matvec_t_into_pooled`]'s column reduction.
/// Fixed grid, same determinism argument as [`PAR_T_CHUNK`].
const PAR_LSE_T_CHUNK: usize = 1024;

/// `out = a @ v` without allocating, on the runtime-dispatched arm.
///
/// Accuracy/speed contract (both arms): within each 64-element block the
/// dot runs in f32 partial lanes (no serial dependency chain); block
/// results are accumulated in f64, so rounding error grows with the
/// block count, not the row length. Sinkhorn scalings span many orders
/// of magnitude — pure-f32 row sums measurably bias small-eps runs,
/// while this scheme matches the old full-f64 accumulator to ~1e-6
/// relative at a multiple of its throughput (EXPERIMENTS.md §Perf, L3
/// iterations 1 and 3). The scalar arm keeps 8 partial lanes per block;
/// the AVX2 arm widens to 32 lanes across four FMA accumulators — same
/// contract, more lanes — and the two arms agree to ≤ 1e-5 relative
/// (`rust/tests/parallel_equivalence.rs`).
pub fn matvec_into(a: &Mat, v: &[f32], out: &mut [f32]) {
    matvec_into_at(simd::active_level(), a, v, out);
}

/// [`matvec_into`] pinned to a dispatch arm (tests/benches; the level is
/// sanitised, so an unsupported arm falls back to scalar).
pub fn matvec_into_at(level: SimdLevel, a: &Mat, v: &[f32], out: &mut [f32]) {
    let level = level.sanitize();
    assert_eq!(a.cols(), v.len(), "matvec: {}x{} @ {}", a.rows(), a.cols(), v.len());
    assert_eq!(a.rows(), out.len(), "matvec: output length");
    for (i, o) in out.iter_mut().enumerate() {
        *o = simd::row_dot(level, a.row(i), v);
    }
}

/// Row-chunked parallel [`matvec_into`].
///
/// Rows are independent, so each task computes a contiguous block of
/// output rows with the *same* per-row kernel as the serial path: the
/// result is bitwise identical to [`matvec_into`] for every pool size
/// (property-tested in `rust/tests/parallel_equivalence.rs`, on both
/// dispatch arms). Small problems and serial pools fall through to the
/// serial loop to skip the spawn overhead.
pub fn matvec_into_pooled(a: &Mat, v: &[f32], out: &mut [f32], pool: &Pool) {
    matvec_into_pooled_at(simd::active_level(), a, v, out, pool);
}

/// [`matvec_into_pooled`] pinned to a dispatch arm.
pub fn matvec_into_pooled_at(level: SimdLevel, a: &Mat, v: &[f32], out: &mut [f32], pool: &Pool) {
    let level = level.sanitize();
    assert_eq!(a.cols(), v.len(), "matvec: {}x{} @ {}", a.rows(), a.cols(), v.len());
    assert_eq!(a.rows(), out.len(), "matvec: output length");
    if pool.threads() <= 1 || a.rows() < 2 * PAR_ROW_CHUNK {
        matvec_into_at(level, a, v, out);
        return;
    }
    let tasks: Vec<(usize, &mut [f32])> = out.chunks_mut(PAR_ROW_CHUNK).enumerate().collect();
    pool.run_tasks(tasks, |(c, chunk)| {
        let base = c * PAR_ROW_CHUNK;
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = simd::row_dot(level, a.row(base + i), v);
        }
    });
}

/// `a @ v`, allocating the output.
pub fn matvec(a: &Mat, v: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0; a.rows()];
    matvec_into(a, v, &mut out);
    out
}

/// `out = a^T @ v` without allocating and without transposing: accumulate
/// rows of `a` scaled by `v[i]` into the output (saxpy). The scalar arm
/// blocks 4 rows per pass (EXPERIMENTS.md §Perf, L3 iteration 2); the
/// AVX2 arm widens to an **8-row × 8-column register-tiled microkernel**
/// — eight broadcast coefficients FMA-accumulated into one 8-wide output
/// register per tile step, touching `out` an eighth as often as the
/// naive loop while still streaming `a` exactly once (L3 iteration 3).
pub fn matvec_t_into(a: &Mat, v: &[f32], out: &mut [f32]) {
    matvec_t_into_at(simd::active_level(), a, v, out);
}

/// [`matvec_t_into`] pinned to a dispatch arm.
pub fn matvec_t_into_at(level: SimdLevel, a: &Mat, v: &[f32], out: &mut [f32]) {
    let level = level.sanitize();
    let (n, k) = a.shape();
    assert_eq!(n, v.len(), "matvec_t: {}x{} ^T @ {}", n, k, v.len());
    assert_eq!(k, out.len(), "matvec_t: output length");
    out.iter_mut().for_each(|x| *x = 0.0);
    simd::saxpy_rows(level, a, v, 0..n, out);
}

/// Row-chunked parallel [`matvec_t_into`].
///
/// Unlike the plain matvec, the transposed apply reduces *across* rows, so
/// parallel execution needs per-chunk partial outputs. The chunk grid is
/// fixed (`PAR_T_CHUNK` = 1024 rows per partial, independent of the thread
/// count) and partials are combined in chunk-index order with f64
/// accumulation on one thread — so the result is deterministic and
/// identical for every pool size, and matches the serial kernel to the
/// chunked-reduction reordering — typically ~1e-6 and bounded well below
/// 1e-5 relative on Sinkhorn factors, whose entries are non-negative
/// (property-tested in `rust/tests/parallel_equivalence.rs`, on both
/// dispatch arms). Single-chunk problems (n ≤ 1024) take the serial
/// allocation-free path directly — a one-partial reduce would be bitwise
/// equal anyway, so thread invariance is unaffected.
pub fn matvec_t_into_pooled(a: &Mat, v: &[f32], out: &mut [f32], pool: &Pool) {
    matvec_t_into_pooled_at(simd::active_level(), a, v, out, pool);
}

/// [`matvec_t_into_pooled`] pinned to a dispatch arm.
pub fn matvec_t_into_pooled_at(level: SimdLevel, a: &Mat, v: &[f32], out: &mut [f32], pool: &Pool) {
    let level = level.sanitize();
    let (n, k) = a.shape();
    assert_eq!(n, v.len(), "matvec_t: {}x{} ^T @ {}", n, k, v.len());
    assert_eq!(k, out.len(), "matvec_t: output length");
    // Single-chunk problems reduce over one partial, which is bitwise
    // equal to the serial kernel — take the allocation-free path for
    // every pool size (thread invariance is preserved: the path depends
    // only on n).
    if n <= PAR_T_CHUNK {
        matvec_t_into_at(level, a, v, out);
        return;
    }
    let nchunks = n.div_ceil(PAR_T_CHUNK);
    let mut partials: Vec<Vec<f32>> = (0..nchunks).map(|_| vec![0.0f32; k]).collect();
    let tasks: Vec<(usize, &mut Vec<f32>)> = partials.iter_mut().enumerate().collect();
    pool.run_tasks(tasks, |(c, buf)| {
        let lo = c * PAR_T_CHUNK;
        let hi = (lo + PAR_T_CHUNK).min(n);
        simd::saxpy_rows(level, a, v, lo..hi, buf);
    });
    // Deterministic single-thread reduce in chunk order, f64 accumulation
    // (arm-independent by construction: plain scalar adds).
    for (j, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for p in &partials {
            acc += p[j] as f64;
        }
        *o = acc as f32;
    }
}

/// `a^T @ v`, allocating the output.
pub fn matvec_t(a: &Mat, v: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0; a.cols()];
    matvec_t_into(a, v, &mut out);
    out
}

/// Row-streamed log-space matvec:
/// `out[i] = logsumexp_j(alpha * a[i, j] + t[j])`.
///
/// This is the row update of log-domain Sinkhorn: with `a` a cost matrix
/// and `alpha = -1/eps` it evaluates `logsumexp_j(log K_ij + t_j)`
/// without ever forming `K`; with `a` a log-factor matrix and
/// `alpha = 1` it is the outer reduction of the factored log-kernel
/// apply. All arithmetic is f64 (log-domain quantities at small eps sit
/// far outside f32 range). On the AVX2 arm the shifted exponentials run
/// through [`crate::special::vexp`] (≤ 2 ulp), which is where the lse
/// path's ≥ 3x single-thread target comes from (EXPERIMENTS.md §Perf,
/// "SIMD core").
pub fn lse_matvec_into(a: &Mat, alpha: f64, t: &[f64], out: &mut [f64]) {
    lse_matvec_into_at(simd::active_level(), a, alpha, t, out);
}

/// [`lse_matvec_into`] pinned to a dispatch arm.
pub fn lse_matvec_into_at(level: SimdLevel, a: &Mat, alpha: f64, t: &[f64], out: &mut [f64]) {
    let level = level.sanitize();
    assert_eq!(a.cols(), t.len(), "lse_matvec: {}x{} @ {}", a.rows(), a.cols(), t.len());
    assert_eq!(a.rows(), out.len(), "lse_matvec: output length");
    for (i, o) in out.iter_mut().enumerate() {
        *o = simd::lse_row(level, a.row(i), alpha, t);
    }
}

/// Row-chunked parallel [`lse_matvec_into`].
///
/// Rows are independent and share the per-row `lse_row` kernel with the
/// serial path, so the result is bitwise identical to [`lse_matvec_into`]
/// for every pool size (property-tested in
/// `rust/tests/parallel_equivalence.rs`, on both dispatch arms). Small
/// problems and serial pools fall through to the serial loop.
pub fn lse_matvec_into_pooled(a: &Mat, alpha: f64, t: &[f64], out: &mut [f64], pool: &Pool) {
    lse_matvec_into_pooled_at(simd::active_level(), a, alpha, t, out, pool);
}

/// [`lse_matvec_into_pooled`] pinned to a dispatch arm.
pub fn lse_matvec_into_pooled_at(
    level: SimdLevel,
    a: &Mat,
    alpha: f64,
    t: &[f64],
    out: &mut [f64],
    pool: &Pool,
) {
    let level = level.sanitize();
    assert_eq!(a.cols(), t.len(), "lse_matvec: {}x{} @ {}", a.rows(), a.cols(), t.len());
    assert_eq!(a.rows(), out.len(), "lse_matvec: output length");
    if pool.threads() <= 1 || a.rows() < 2 * PAR_LSE_ROW_CHUNK {
        lse_matvec_into_at(level, a, alpha, t, out);
        return;
    }
    let tasks: Vec<(usize, &mut [f64])> = out.chunks_mut(PAR_LSE_ROW_CHUNK).enumerate().collect();
    pool.run_tasks(tasks, |(c, chunk)| {
        let base = c * PAR_LSE_ROW_CHUNK;
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = simd::lse_row(level, a.row(base + i), alpha, t);
        }
    });
}

/// Column-reducing log-space matvec:
/// `out[j] = logsumexp_i(alpha * a[i, j] + u[i])` — the transposed
/// (column) update of log-domain Sinkhorn, f64 throughout.
pub fn lse_matvec_t_into(a: &Mat, alpha: f64, u: &[f64], out: &mut [f64]) {
    lse_matvec_t_into_at(simd::active_level(), a, alpha, u, out);
}

/// [`lse_matvec_t_into`] pinned to a dispatch arm.
pub fn lse_matvec_t_into_at(level: SimdLevel, a: &Mat, alpha: f64, u: &[f64], out: &mut [f64]) {
    let level = level.sanitize();
    let (n, k) = a.shape();
    assert_eq!(n, u.len(), "lse_matvec_t: {}x{} ^T @ {}", n, k, u.len());
    assert_eq!(k, out.len(), "lse_matvec_t: output length");
    let mut mx = vec![f64::NEG_INFINITY; k];
    let mut sum = vec![0.0f64; k];
    simd::lse_accum_rows(level, a, alpha, u, 0..n, &mut mx, &mut sum);
    simd::lse_finish(level, &mx, &sum, out);
}

/// Row-chunked parallel [`lse_matvec_t_into`].
///
/// Like [`matvec_t_into_pooled`], the reduction runs across rows, so
/// parallel execution keeps per-chunk partials — here `(max, sumexp)`
/// pairs — on a *fixed* grid (`PAR_LSE_T_CHUNK` = 1024 rows per partial,
/// independent of the thread count) and merges them in chunk-index order
/// on one thread: `M = max_c m_c`, `S = sum_c s_c * exp(m_c - M)`. The
/// result is therefore identical for every pool size (the code path
/// depends only on `n`), and matches the serial kernel up to the chunked
/// merge's f64 rounding — property-tested in
/// `rust/tests/parallel_equivalence.rs` on both dispatch arms (the merge
/// itself is plain scalar f64 on every arm). Single-chunk problems
/// (`n ≤ 1024`) take the serial path directly for every pool size.
pub fn lse_matvec_t_into_pooled(a: &Mat, alpha: f64, u: &[f64], out: &mut [f64], pool: &Pool) {
    lse_matvec_t_into_pooled_at(simd::active_level(), a, alpha, u, out, pool);
}

/// [`lse_matvec_t_into_pooled`] pinned to a dispatch arm.
pub fn lse_matvec_t_into_pooled_at(
    level: SimdLevel,
    a: &Mat,
    alpha: f64,
    u: &[f64],
    out: &mut [f64],
    pool: &Pool,
) {
    let level = level.sanitize();
    let (n, k) = a.shape();
    assert_eq!(n, u.len(), "lse_matvec_t: {}x{} ^T @ {}", n, k, u.len());
    assert_eq!(k, out.len(), "lse_matvec_t: output length");
    if n <= PAR_LSE_T_CHUNK {
        lse_matvec_t_into_at(level, a, alpha, u, out);
        return;
    }
    let nchunks = n.div_ceil(PAR_LSE_T_CHUNK);
    let mut partials: Vec<(Vec<f64>, Vec<f64>)> =
        (0..nchunks).map(|_| (vec![f64::NEG_INFINITY; k], vec![0.0f64; k])).collect();
    let tasks: Vec<(usize, &mut (Vec<f64>, Vec<f64>))> = partials.iter_mut().enumerate().collect();
    pool.run_tasks(tasks, |(c, (mx, sum))| {
        let lo = c * PAR_LSE_T_CHUNK;
        let hi = (lo + PAR_LSE_T_CHUNK).min(n);
        simd::lse_accum_rows(level, a, alpha, u, lo..hi, mx, sum);
    });
    // Deterministic single-thread merge in chunk order (scalar on every
    // arm, so the merge never contributes a cross-arm difference).
    for (j, o) in out.iter_mut().enumerate() {
        let mut m = f64::NEG_INFINITY;
        for (mx, _) in &partials {
            if mx[j] > m {
                m = mx[j];
            }
        }
        if !m.is_finite() {
            *o = m;
            continue;
        }
        let mut s = 0.0f64;
        for (mx, sum) in &partials {
            if mx[j].is_finite() {
                s += sum[j] * (mx[j] - m).exp();
            }
        }
        *o = m + s.ln();
    }
}

/// Column-blocked [`matvec_into`]: `out.row(k) = a @ vs.row(k)` for every
/// pair row (inputs and outputs pair-major: B×cols in, B×rows out).
///
/// `a` is streamed row-by-row once, each row dotted against all B input
/// vectors — the fused form the batched Sinkhorn engine rides. Every
/// entry comes from the same `row_dot` kernel as the vector variant, so
/// row `k` of the output is bitwise identical to `matvec_into(a,
/// vs.row(k), ..)` for any B, on either dispatch arm.
pub fn matmat_into(a: &Mat, vs: &Mat, out: &mut Mat) {
    matmat_into_at(simd::active_level(), a, vs, out);
}

/// [`matmat_into`] pinned to a dispatch arm.
pub fn matmat_into_at(level: SimdLevel, a: &Mat, vs: &Mat, out: &mut Mat) {
    let level = level.sanitize();
    let b = vs.rows();
    assert_eq!(a.cols(), vs.cols(), "matmat: {}x{} @ {}x{}^T", a.rows(), a.cols(), b, vs.cols());
    assert_eq!(out.shape(), (b, a.rows()), "matmat: output shape");
    for i in 0..a.rows() {
        let row = a.row(i);
        for k in 0..b {
            out[(k, i)] = simd::row_dot(level, row, vs.row(k));
        }
    }
}

/// Row-chunked parallel [`matmat_into`].
///
/// The task grid is (pair, fixed row chunk): each task fills a contiguous
/// block of one pair row of the output with the shared `row_dot` kernel,
/// so the result is bitwise identical to the serial form — and to the
/// per-pair vector applies — for every pool size.
pub fn matmat_into_pooled(a: &Mat, vs: &Mat, out: &mut Mat, pool: &Pool) {
    matmat_into_pooled_at(simd::active_level(), a, vs, out, pool);
}

/// [`matmat_into_pooled`] pinned to a dispatch arm.
pub fn matmat_into_pooled_at(level: SimdLevel, a: &Mat, vs: &Mat, out: &mut Mat, pool: &Pool) {
    let level = level.sanitize();
    let b = vs.rows();
    assert_eq!(a.cols(), vs.cols(), "matmat: {}x{} @ {}x{}^T", a.rows(), a.cols(), b, vs.cols());
    assert_eq!(out.shape(), (b, a.rows()), "matmat: output shape");
    if pool.threads() <= 1 || a.rows() < 2 * PAR_ROW_CHUNK {
        matmat_into_at(level, a, vs, out);
        return;
    }
    let n = a.rows();
    let tasks: Vec<(usize, usize, &mut [f32])> = out
        .data_mut()
        .chunks_mut(n)
        .enumerate()
        .flat_map(|(k, prow)| {
            prow.chunks_mut(PAR_ROW_CHUNK).enumerate().map(move |(c, chunk)| (k, c, chunk))
        })
        .collect();
    pool.run_tasks(tasks, |(k, c, chunk)| {
        let base = c * PAR_ROW_CHUNK;
        let vrow = vs.row(k);
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = simd::row_dot(level, a.row(base + i), vrow);
        }
    });
}

/// Column-blocked [`matvec_t_into`]: `out.row(k) = a^T @ us.row(k)` for
/// every pair row (us: B×rows, out: B×cols, both pair-major).
pub fn matmat_t_into(a: &Mat, us: &Mat, out: &mut Mat) {
    matmat_t_into_at(simd::active_level(), a, us, out);
}

/// [`matmat_t_into`] pinned to a dispatch arm.
pub fn matmat_t_into_at(level: SimdLevel, a: &Mat, us: &Mat, out: &mut Mat) {
    let level = level.sanitize();
    let (n, k) = a.shape();
    let b = us.rows();
    assert_eq!(us.cols(), n, "matmat_t: {}x{} ^T @ {}x{}^T", n, k, b, us.cols());
    assert_eq!(out.shape(), (b, k), "matmat_t: output shape");
    out.data_mut().iter_mut().for_each(|x| *x = 0.0);
    simd::saxpy_rows_multi(level, a, us, 0..n, out);
}

/// Row-chunked parallel [`matmat_t_into`].
///
/// Same fixed `PAR_T_CHUNK` grid and chunk-ordered f64 merge as
/// [`matvec_t_into_pooled`], applied per pair row — so each output row is
/// bitwise identical to the pooled vector kernel's output at every pool
/// size (including the `n ≤ 1024` serial fall-through, which branches on
/// `n` alone exactly like the vector variant).
pub fn matmat_t_into_pooled(a: &Mat, us: &Mat, out: &mut Mat, pool: &Pool) {
    matmat_t_into_pooled_at(simd::active_level(), a, us, out, pool);
}

/// [`matmat_t_into_pooled`] pinned to a dispatch arm.
pub fn matmat_t_into_pooled_at(level: SimdLevel, a: &Mat, us: &Mat, out: &mut Mat, pool: &Pool) {
    let level = level.sanitize();
    let (n, k) = a.shape();
    let b = us.rows();
    assert_eq!(us.cols(), n, "matmat_t: {}x{} ^T @ {}x{}^T", n, k, b, us.cols());
    assert_eq!(out.shape(), (b, k), "matmat_t: output shape");
    if n <= PAR_T_CHUNK {
        matmat_t_into_at(level, a, us, out);
        return;
    }
    let nchunks = n.div_ceil(PAR_T_CHUNK);
    let mut partials: Vec<Mat> = (0..nchunks).map(|_| Mat::zeros(b, k)).collect();
    let tasks: Vec<(usize, &mut Mat)> = partials.iter_mut().enumerate().collect();
    pool.run_tasks(tasks, |(c, buf)| {
        let lo = c * PAR_T_CHUNK;
        simd::saxpy_rows_multi(level, a, us, lo..(lo + PAR_T_CHUNK).min(n), buf);
    });
    // Deterministic single-thread reduce in chunk order, f64 accumulation
    // (per pair row, identical to the vector kernel's merge).
    for p in 0..b {
        for (j, o) in out.row_mut(p).iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for part in &partials {
                acc += part[(p, j)] as f64;
            }
            *o = acc as f32;
        }
    }
}

/// Column-blocked [`lse_matvec_into`]: `outs[k][i] = logsumexp_j(alpha *
/// a[i, j] + ts[k][j])` for every pair `k`, streaming each row of `a`
/// once for all B inputs. Bitwise identical per pair to the vector form
/// (shared `lse_row` kernel, on either arm).
pub fn lse_matmat_into(a: &Mat, alpha: f64, ts: &[Vec<f64>], outs: &mut [Vec<f64>]) {
    lse_matmat_into_at(simd::active_level(), a, alpha, ts, outs);
}

/// [`lse_matmat_into`] pinned to a dispatch arm.
pub fn lse_matmat_into_at(
    level: SimdLevel,
    a: &Mat,
    alpha: f64,
    ts: &[Vec<f64>],
    outs: &mut [Vec<f64>],
) {
    let level = level.sanitize();
    assert_eq!(ts.len(), outs.len(), "lse_matmat: {} inputs vs {} outputs", ts.len(), outs.len());
    for (t, o) in ts.iter().zip(outs.iter()) {
        assert_eq!(a.cols(), t.len(), "lse_matmat: input length");
        assert_eq!(a.rows(), o.len(), "lse_matmat: output length");
    }
    for i in 0..a.rows() {
        let row = a.row(i);
        for (t, o) in ts.iter().zip(outs.iter_mut()) {
            o[i] = simd::lse_row(level, row, alpha, t);
        }
    }
}

/// Row-chunked parallel [`lse_matmat_into`]: (pair, fixed row chunk) task
/// grid over the shared `lse_row` kernel — bitwise identical to the
/// serial form and the per-pair vector applies at every pool size.
pub fn lse_matmat_into_pooled(
    a: &Mat,
    alpha: f64,
    ts: &[Vec<f64>],
    outs: &mut [Vec<f64>],
    pool: &Pool,
) {
    lse_matmat_into_pooled_at(simd::active_level(), a, alpha, ts, outs, pool);
}

/// [`lse_matmat_into_pooled`] pinned to a dispatch arm.
pub fn lse_matmat_into_pooled_at(
    level: SimdLevel,
    a: &Mat,
    alpha: f64,
    ts: &[Vec<f64>],
    outs: &mut [Vec<f64>],
    pool: &Pool,
) {
    let level = level.sanitize();
    assert_eq!(ts.len(), outs.len(), "lse_matmat: {} inputs vs {} outputs", ts.len(), outs.len());
    for (t, o) in ts.iter().zip(outs.iter()) {
        assert_eq!(a.cols(), t.len(), "lse_matmat: input length");
        assert_eq!(a.rows(), o.len(), "lse_matmat: output length");
    }
    if pool.threads() <= 1 || a.rows() < 2 * PAR_LSE_ROW_CHUNK {
        lse_matmat_into_at(level, a, alpha, ts, outs);
        return;
    }
    let tasks: Vec<(usize, usize, &mut [f64])> = outs
        .iter_mut()
        .enumerate()
        .flat_map(|(p, o)| {
            let slice: &mut [f64] = o;
            slice.chunks_mut(PAR_LSE_ROW_CHUNK).enumerate().map(move |(c, chunk)| (p, c, chunk))
        })
        .collect();
    pool.run_tasks(tasks, |(p, c, chunk)| {
        let base = c * PAR_LSE_ROW_CHUNK;
        let t = &ts[p];
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = simd::lse_row(level, a.row(base + i), alpha, t);
        }
    });
}

/// Column-blocked [`lse_matvec_t_into`]: the transposed logsumexp
/// reduction for every pair (delegates to the vector kernel per pair —
/// the two-pass reduction has no row-block to fuse across pairs serially;
/// the pooled variant fuses at chunk granularity instead).
pub fn lse_matmat_t_into(a: &Mat, alpha: f64, us: &[Vec<f64>], outs: &mut [Vec<f64>]) {
    lse_matmat_t_into_at(simd::active_level(), a, alpha, us, outs);
}

/// [`lse_matmat_t_into`] pinned to a dispatch arm.
pub fn lse_matmat_t_into_at(
    level: SimdLevel,
    a: &Mat,
    alpha: f64,
    us: &[Vec<f64>],
    outs: &mut [Vec<f64>],
) {
    assert_eq!(
        us.len(),
        outs.len(),
        "lse_matmat_t: {} inputs vs {} outputs",
        us.len(),
        outs.len()
    );
    for (u, o) in us.iter().zip(outs.iter_mut()) {
        lse_matvec_t_into_at(level, a, alpha, u, o);
    }
}

/// Row-chunked parallel [`lse_matmat_t_into`].
///
/// The task grid is (pair, fixed `PAR_LSE_T_CHUNK` row chunk) with
/// per-task `(max, sumexp)` partials merged in chunk order per pair —
/// exactly [`lse_matvec_t_into_pooled`]'s reduction applied to each pair,
/// so every pair's output is bitwise identical to the pooled vector
/// kernel's at any pool size (the `n ≤ 1024` fall-through branches on `n`
/// alone, like the vector variant).
pub fn lse_matmat_t_into_pooled(
    a: &Mat,
    alpha: f64,
    us: &[Vec<f64>],
    outs: &mut [Vec<f64>],
    pool: &Pool,
) {
    lse_matmat_t_into_pooled_at(simd::active_level(), a, alpha, us, outs, pool);
}

/// [`lse_matmat_t_into_pooled`] pinned to a dispatch arm.
pub fn lse_matmat_t_into_pooled_at(
    level: SimdLevel,
    a: &Mat,
    alpha: f64,
    us: &[Vec<f64>],
    outs: &mut [Vec<f64>],
    pool: &Pool,
) {
    let level = level.sanitize();
    let (n, k) = a.shape();
    assert_eq!(
        us.len(),
        outs.len(),
        "lse_matmat_t: {} inputs vs {} outputs",
        us.len(),
        outs.len()
    );
    for (u, o) in us.iter().zip(outs.iter()) {
        assert_eq!(u.len(), n, "lse_matmat_t: input length");
        assert_eq!(o.len(), k, "lse_matmat_t: output length");
    }
    if n <= PAR_LSE_T_CHUNK {
        lse_matmat_t_into_at(level, a, alpha, us, outs);
        return;
    }
    let b = us.len();
    let nchunks = n.div_ceil(PAR_LSE_T_CHUNK);
    // Partial (max, sumexp) pairs laid out pair-major: index p * nchunks + c.
    let mut partials: Vec<(Vec<f64>, Vec<f64>)> =
        (0..b * nchunks).map(|_| (vec![f64::NEG_INFINITY; k], vec![0.0f64; k])).collect();
    let tasks: Vec<(usize, &mut (Vec<f64>, Vec<f64>))> = partials.iter_mut().enumerate().collect();
    pool.run_tasks(tasks, |(idx, (mx, sum))| {
        let (p, c) = (idx / nchunks, idx % nchunks);
        let lo = c * PAR_LSE_T_CHUNK;
        simd::lse_accum_rows(level, a, alpha, &us[p], lo..(lo + PAR_LSE_T_CHUNK).min(n), mx, sum);
    });
    // Deterministic single-thread merge in chunk order, per pair.
    for (p, o) in outs.iter_mut().enumerate() {
        let parts = &partials[p * nchunks..(p + 1) * nchunks];
        for (j, oj) in o.iter_mut().enumerate() {
            let mut m = f64::NEG_INFINITY;
            for (mx, _) in parts {
                if mx[j] > m {
                    m = mx[j];
                }
            }
            if !m.is_finite() {
                *oj = m;
                continue;
            }
            let mut s = 0.0f64;
            for (mx, sum) in parts {
                if mx[j].is_finite() {
                    s += sum[j] * (mx[j] - m).exp();
                }
            }
            *oj = m + s.ln();
        }
    }
}

/// Blocked `a @ b` (off the Sinkhorn hot path; used by Nyström, the GAN
/// forward pass and tests — portable scalar on every dispatch arm).
pub fn matmul(a: &Mat, b: &Mat) -> Mat {
    assert_eq!(a.cols(), b.rows(), "matmul: {}x{} @ {}x{}", a.rows(), a.cols(), b.rows(), b.cols());
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Mat::zeros(m, n);
    // i-k-j loop order: the inner loop is a saxpy over contiguous rows of
    // b and c — the standard cache-friendly dense order.
    for i in 0..m {
        let arow = a.row(i);
        let crow = c.row_mut(i);
        for (kk, &aik) in arow.iter().enumerate().take(k) {
            if aik == 0.0 {
                continue;
            }
            let brow = b.row(kk);
            for (cv, &bv) in crow.iter_mut().zip(brow) {
                *cv += aik * bv;
            }
        }
    }
    c
}

/// Dot product with f64 accumulation.
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| (a as f64) * (b as f64)).sum::<f64>() as f32
}

/// `y += alpha * x`.
pub fn axpy(alpha: f32, x: &[f32], y: &mut [f32]) {
    assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// `x *= alpha`.
pub fn scale(x: &mut [f32], alpha: f32) {
    for xi in x {
        *xi *= alpha;
    }
}

/// Sum with f64 accumulation.
pub fn sum(x: &[f32]) -> f32 {
    x.iter().map(|&v| v as f64).sum::<f64>() as f32
}

/// L1 norm.
pub fn l1_norm(x: &[f32]) -> f32 {
    x.iter().map(|&v| (v as f64).abs()).sum::<f64>() as f32
}

/// `sum_i |x_i - y_i|` — Alg. 1's marginal-error monitor.
pub fn l1_diff(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| ((a - b) as f64).abs()).sum::<f64>() as f32
}

/// `max_i |x_i - y_i|`.
pub fn max_abs_diff(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(&a, &b)| (a - b).abs()).fold(0.0, f32::max)
}

/// Numerically-stable log(sum(exp(x))).
pub fn logsumexp(x: &[f32]) -> f32 {
    assert!(!x.is_empty());
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    if !m.is_finite() {
        return m;
    }
    let s: f64 = x.iter().map(|&v| ((v - m) as f64).exp()).sum();
    m + (s.ln() as f32)
}

/// In-place softmax with temperature `t` (higher `t` sharpens — the
/// paper's Fig. 6 uses a temperature-1000 softmax to reveal barycenter
/// peaks).
pub fn softmax_inplace(x: &mut [f32], t: f32) {
    let m = x.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f64;
    for v in x.iter_mut() {
        *v = ((*v - m) * t).exp();
        z += *v as f64;
    }
    let inv = (1.0 / z) as f32;
    for v in x.iter_mut() {
        *v *= inv;
    }
}
