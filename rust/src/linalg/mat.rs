//! Row-major dense `f32` matrix.

use std::fmt;
use std::ops::{Index, IndexMut};

/// A dense row-major matrix of `f32`.
///
/// Row-major is the layout the Sinkhorn hot paths want: `matvec` streams
/// rows contiguously and `matvec_t` accumulates over rows with a
/// column-contiguous output block that stays in registers/L1.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// All-one matrix.
    pub fn ones(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![1.0; rows * cols] }
    }

    /// Build from a generator over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Mat { rows, cols, data }
    }

    /// Take ownership of a row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "buffer length {} != {rows}x{cols}", data.len());
        Mat { rows, cols, data }
    }

    /// Build from row slices (all the same length).
    pub fn from_rows(rows: &[Vec<f32>]) -> Self {
        assert!(!rows.is_empty(), "from_rows: empty");
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for r in rows {
            assert_eq!(r.len(), cols, "from_rows: ragged rows");
            data.extend_from_slice(r);
        }
        Mat { rows: rows.len(), cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// (rows, cols).
    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    /// The underlying row-major buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Borrow row `i` as a contiguous slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Copy column `j` out (columns are strided in row-major).
    pub fn col_copy(&self, j: usize) -> Vec<f32> {
        assert!(j < self.cols);
        (0..self.rows).map(|i| self.data[i * self.cols + j]).collect()
    }

    /// Materialised transpose (used off the hot path only; the hot path
    /// uses `matvec_t` which never transposes).
    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on large matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Elementwise map, returning a new matrix.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Minimum entry (panics on empty).
    pub fn min_entry(&self) -> f32 {
        self.data.iter().cloned().fold(f32::INFINITY, f32::min)
    }

    /// Maximum entry (panics on empty).
    pub fn max_entry(&self) -> f32 {
        self.data.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt() as f32
    }

    /// True if any entry is NaN or infinite.
    pub fn has_non_finite(&self) -> bool {
        self.data.iter().any(|x| !x.is_finite())
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        let show = self.rows.min(6);
        for i in 0..show {
            let cols = self.cols.min(8);
            let vals: Vec<String> = (0..cols).map(|j| format!("{:.4}", self[(i, j)])).collect();
            writeln!(f, "  [{}{}]", vals.join(", "), if self.cols > 8 { ", …" } else { "" })?;
        }
        if self.rows > show {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}
