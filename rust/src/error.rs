//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the offline crate set has no
//! `thiserror`); the messages match the previous derive-generated ones
//! exactly so log scrapers and tests keep working.

use std::fmt;

/// Errors surfaced by the linear-sinkhorn stack.
#[derive(Debug)]
pub enum Error {
    /// Sinkhorn iterations produced a non-finite scaling (typically a dense
    /// kernel with underflowed rows at very small epsilon, or a Nyström
    /// approximation with non-positive entries — the failure mode the
    /// paper's positive features avoid by construction).
    SinkhornDiverged { iter: usize, reason: String },

    /// A low-rank kernel approximation lost positivity (Nyström baseline).
    NotPositive { min_entry: f64, rank: usize },

    /// Shape mismatch between operands.
    Shape(String),

    /// Config file / CLI problems.
    Config(String),

    /// AOT artifact registry problems (missing file, bad manifest…).
    Artifact(String),

    /// PJRT / XLA runtime failure.
    Runtime(String),

    /// The coordinator rejected a request (shed load / shut down), or a
    /// shard-serving failure the retry policy could not absorb (all
    /// workers dead, retry budget exhausted).
    Service(String),

    /// Malformed wire frame (bad magic, truncated header, payload length
    /// mismatch, unknown column dtype…). Corrupt bytes must surface as
    /// this typed error, never as a panic or a wrong answer.
    Wire(String),

    /// Admission control shed the request: the coordinator's bounded
    /// in-flight budget is full (or the submit queue overflowed). Unlike
    /// [`Error::Service`] this is retryable by construction — nothing was
    /// attempted, the caller should back off and resubmit.
    Overloaded(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::SinkhornDiverged { iter, reason } => {
                write!(f, "sinkhorn diverged at iteration {iter}: {reason}")
            }
            Error::NotPositive { min_entry, rank } => write!(
                f,
                "kernel approximation is not positive: min entry {min_entry:e} (rank {rank})"
            ),
            Error::Shape(s) => write!(f, "shape mismatch: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Artifact(s) => write!(f, "artifact: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Service(s) => write!(f, "service: {s}"),
            Error::Wire(s) => write!(f, "wire: {s}"),
            Error::Overloaded(s) => write!(f, "overloaded: {s}"),
            Error::Io(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            // Matches thiserror's `#[error(transparent)]`: Display AND
            // source() both forward to the inner error, so chain
            // printers don't show the io message twice.
            Error::Io(e) => e.source(),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
