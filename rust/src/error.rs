//! Crate-wide error type.

use thiserror::Error;

/// Errors surfaced by the linear-sinkhorn stack.
#[derive(Error, Debug)]
pub enum Error {
    /// Sinkhorn iterations produced a non-finite scaling (typically a dense
    /// kernel with underflowed rows at very small epsilon, or a Nyström
    /// approximation with non-positive entries — the failure mode the
    /// paper's positive features avoid by construction).
    #[error("sinkhorn diverged at iteration {iter}: {reason}")]
    SinkhornDiverged { iter: usize, reason: String },

    /// A low-rank kernel approximation lost positivity (Nyström baseline).
    #[error("kernel approximation is not positive: min entry {min_entry:e} (rank {rank})")]
    NotPositive { min_entry: f64, rank: usize },

    /// Shape mismatch between operands.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Config file / CLI problems.
    #[error("config: {0}")]
    Config(String),

    /// AOT artifact registry problems (missing file, bad manifest…).
    #[error("artifact: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failure.
    #[error("runtime: {0}")]
    Runtime(String),

    /// The coordinator rejected a request (shed load / shut down).
    #[error("service: {0}")]
    Service(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Runtime(format!("{e:?}"))
    }
}
