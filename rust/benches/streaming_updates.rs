//! Streaming-session update/query bench: warm-started incremental
//! queries vs cold from-scratch solves under single-point churn.
//!
//! The EXPERIMENTS.md §Online updates anchor. Per round the table
//! records, on one long-lived [`StreamingSession`]:
//!   * `update` — wall clock of applying one single-point swap
//!     (O(r·d): one feature row re-evaluated, nothing else touched),
//!   * `warm`   — the incremental query's iteration count (dual
//!     warm-started through the provenance remap),
//!   * `cold`   — a from-scratch baseline: a fresh session opened on
//!     the *same* snapshot with the *same* map, solved cold, so the
//!     iteration gap is exactly what warm-starting buys,
//!   * the relative objective deviation warm vs cold (same support,
//!     same kernel — tolerance-level agreement expected).
//!
//! The acceptance bar is >= 5x fewer iterations for the warm query than
//! the cold baseline for single-point swaps at n = 1e4, r = 128,
//! eps = 1e-2.
//!
//! Run: `cargo bench --bench streaming_updates`
//!
//! Setting `BENCH_SMOKE=1` overrides every size knob with CI-scale
//! values (the `bench-smoke` job's quick mode); setting
//! `BENCH_JSON=<path>` additionally appends the table there in
//! JSON-lines form (see `bench::Table::emit`).

use linear_sinkhorn::bench::{fmt_secs, Table};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

fn main() {
    let args = ArgSpec::new(
        "streaming_updates",
        "warm-started incremental session queries vs cold from-scratch solves",
    )
    .opt("n", "10000", "samples per cloud")
    .opt("features", "128", "positive random features r")
    .opt("eps", "0.01", "regularisation eps")
    .opt("rounds", "8", "single-point-swap rounds (one warm query each)")
    .opt("max-iters", "20000", "iteration cap per solve")
    .opt("seed", "0", "RNG seed")
    .opt("csv", "target/streaming_updates.csv", "csv output")
    .parse();

    // CI quick mode: small cloud, moderate eps — enough to smoke the
    // update path, the warm/cold split, and the JSON artifact.
    let smoke = std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0");
    let (n, r, eps, rounds, max_iters) = if smoke {
        println!("(BENCH_SMOKE: reduced sizes)");
        (600, 48, 0.05, 4, 4000)
    } else {
        (
            args.get_usize("n"),
            args.get_usize("features"),
            args.get_f64("eps"),
            args.get_usize("rounds"),
            args.get_usize("max-iters"),
        )
    };
    let seed = args.get_u64("seed");
    let mut rng = Rng::seed_from(seed);
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    let dim = mu.dim();

    let cfg = SessionConfig {
        sinkhorn: SinkhornConfig { epsilon: eps, max_iters, ..SinkhornConfig::default() },
        rank: r,
        seed,
        solver_threads: 1,
    };
    let mut session = StreamingSession::new(&mu, &nu, cfg.clone()).expect("open session");

    let mut t = Table::new(
        "Streaming updates: warm incremental queries vs cold from-scratch (1-pt swap)",
        &["round", "update", "warm iters", "cold iters", "speedup", "warm vs cold obj"],
    );

    // Round 0: the session's own cold solve (nothing to warm-start from).
    let first = session.query().expect("initial query");
    t.row(vec![
        "0".into(),
        "-".into(),
        "-".into(),
        first.iterations.to_string(),
        "-".into(),
        "-".into(),
    ]);

    let mut warm_total = 0usize;
    let mut cold_total = 0usize;
    for round in 1..=rounds {
        let sw = Stopwatch::start();
        session
            .update(&[SessionOp::SwapX {
                index: rng.uniform_usize(n),
                point: (0..dim).map(|_| rng.normal_f32()).collect(),
                weight: 1.0 / n as f32,
            }])
            .expect("apply swap");
        let update_secs = sw.elapsed_secs();

        let warm = session.query().expect("warm query");
        assert!(warm.warm_started, "a single swap must keep the dual warm");

        // Cold baseline on the identical support: fresh session sharing
        // the map Arc, so the only difference is the missing dual.
        let (cmu, cnu) = session.state().snapshot();
        let map = session.state().map().clone();
        let mut scratch =
            StreamingSession::with_map(&cmu, &cnu, map, cfg.clone()).expect("open scratch");
        let cold = scratch.query().expect("cold query");

        warm_total += warm.iterations;
        cold_total += cold.iterations;
        let rel = (warm.objective - cold.objective).abs() / cold.objective.abs().max(1e-12);
        t.row(vec![
            round.to_string(),
            fmt_secs(update_secs),
            warm.iterations.to_string(),
            cold.iterations.to_string(),
            format!("{:.2}x", cold.iterations as f64 / warm.iterations.max(1) as f64),
            format!("{rel:.2e}"),
        ]);
    }

    let speedup = cold_total as f64 / warm_total.max(1) as f64;
    t.row(vec![
        "total".into(),
        "-".into(),
        warm_total.to_string(),
        cold_total.to_string(),
        format!("{speedup:.2}x"),
        "-".into(),
    ]);
    t.emit(Some(args.get_str("csv")));

    // Raw update throughput: single-point swaps applied back to back,
    // no query in between — the O(r·d) per-op cost in isolation.
    let burst = if smoke { 2000 } else { 20000 };
    let sw = Stopwatch::start();
    for _ in 0..burst {
        session
            .update(&[SessionOp::SwapX {
                index: rng.uniform_usize(n),
                point: (0..dim).map(|_| rng.normal_f32()).collect(),
                weight: 1.0 / n as f32,
            }])
            .expect("burst swap");
    }
    let secs = sw.elapsed_secs();
    println!("\nupdate throughput: {burst} single-point swaps in {} ({:.0} ops/s)",
        fmt_secs(secs),
        burst as f64 / secs
    );
    println!(
        "acceptance bar: warm >= 5x fewer iterations than cold for single-point swaps \
         at n=10000, r=128, eps=1e-2 (EXPERIMENTS.md §Online updates); this run: {speedup:.2}x"
    );
}
