//! L3 coordinator benchmark (ours, not a paper figure): throughput and
//! latency quantiles of the divergence service under an open-loop burst
//! workload, as a function of worker count and batcher policy. This is the
//! bench the §Perf pass iterates against.
//!
//! Run: `cargo bench --bench coordinator_throughput`

use linear_sinkhorn::bench::Table;
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::config::{BatcherConfig, ServiceConfig, SinkhornConfig};
use linear_sinkhorn::coordinator::Service;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

fn run_load(workers: usize, max_batch: usize, n_req: usize, n: usize) -> (f64, f64, f64, u64) {
    let cfg = ServiceConfig {
        workers,
        batcher: BatcherConfig { max_batch, max_delay_us: 200, queue_depth: 4096 },
        sinkhorn: SinkhornConfig {
            epsilon: 0.5,
            max_iters: 500,
            tol: 1e-4,
            check_every: 10,
            ..Default::default()
        },
        num_features: 128,
        solver_threads: 1,
        cache_capacity: 8,
    };
    let svc = Service::start(cfg);
    let h = svc.handle();
    let mut rng = Rng::seed_from(1);
    // Pre-generate the workload so generation isn't on the clock.
    let workload: Vec<(Measure, Measure)> =
        (0..n_req).map(|_| data::gaussian_blobs(n, &mut rng)).collect();
    let sw = Stopwatch::start();
    let mut pendings = Vec::with_capacity(n_req);
    for (mu, nu) in workload {
        if let Ok(p) = h.submit(mu, nu) {
            pendings.push(p);
        }
    }
    let mut latencies: Vec<u64> = Vec::new();
    let mut shed = n_req - pendings.len();
    for p in pendings {
        match p.wait() {
            Ok(resp) => latencies.push(resp.latency_us),
            Err(_) => shed += 1,
        }
    }
    let total = sw.elapsed_secs();
    latencies.sort_unstable();
    let q = |f: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        latencies[((latencies.len() - 1) as f64 * f) as usize] as f64 / 1e3
    };
    drop(h);
    svc.shutdown();
    (latencies.len() as f64 / total, q(0.5), q(0.99), shed as u64)
}

fn main() {
    let args = ArgSpec::new("coord", "divergence service throughput/latency")
        .opt("requests", "64", "requests per configuration")
        .opt("n", "400", "samples per cloud")
        .opt("csv", "target/coordinator.csv", "csv output")
        .parse();
    let n_req = args.get_usize("requests");
    let n = args.get_usize("n");

    let mut t = Table::new(
        "Coordinator throughput (open-loop burst)",
        &["workers", "max_batch", "req/s", "p50 ms", "p99 ms", "shed"],
    );
    for &workers in &[1usize, 2, 4, 8] {
        for &mb in &[1usize, 8, 32] {
            let (rps, p50, p99, shed) = run_load(workers, mb, n_req, n);
            t.row(vec![
                workers.to_string(),
                mb.to_string(),
                format!("{rps:.1}"),
                format!("{p50:.1}"),
                format!("{p99:.1}"),
                shed.to_string(),
            ]);
        }
    }
    t.emit(Some(args.get_str("csv")));
}
