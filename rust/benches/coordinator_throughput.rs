//! L3 coordinator benchmark (ours, not a paper figure): throughput and
//! latency quantiles of the divergence service under an open-loop burst
//! workload, as a function of worker count and batcher policy. This is the
//! bench the §Perf pass iterates against.
//!
//! Two workloads:
//!
//! * **mixed** — every request carries its own clouds (no two requests
//!   can fuse); sweeps workers × batcher `max_batch` as before.
//! * **shared-support** — every request re-weights one common cloud pair
//!   (the reference-distribution serving pattern), so requests are
//!   fusable onto the batched multi-pair solve engine; sweeps the
//!   `sinkhorn.max_batch` fuse-width cap with `1` as the sequential
//!   baseline. The acceptance bar is the batched case beating sequential
//!   at width ≥ 4 on the release build (EXPERIMENTS.md §Throughput).
//!
//! A third table reruns the shared-support workload with fuse groups
//! delegated through the shard scatter/gather tier
//! (`--shard-workers`, see `linear_sinkhorn::shard`), sweeping the shard
//! worker count with `0` (in-process solve) as the baseline. It measures
//! the wire-format + scatter/gather overhead against multi-worker
//! parallelism; results are bitwise identical at every point
//! (EXPERIMENTS.md §Throughput multi-worker).
//!
//! Setting `BENCH_SMOKE=1` shrinks every knob to CI scale;
//! `BENCH_JSON=<path>` appends each table there as JSON lines.
//!
//! Run: `cargo bench --bench coordinator_throughput`

use linear_sinkhorn::bench::Table;
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::config::{BatcherConfig, ServiceConfig, SinkhornConfig};
use linear_sinkhorn::coordinator::Service;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

fn service_cfg(workers: usize, max_batch: usize, fuse_width: usize) -> ServiceConfig {
    ServiceConfig {
        workers,
        batcher: BatcherConfig { max_batch, max_delay_us: 200, queue_depth: 4096 },
        sinkhorn: SinkhornConfig {
            epsilon: 0.5,
            max_iters: 500,
            tol: 1e-4,
            check_every: 10,
            max_batch: fuse_width,
            ..Default::default()
        },
        num_features: 128,
        solver_threads: 1,
        cache_capacity: 8,
        shard_workers: 0,
        ..Default::default()
    }
}

/// Drive `workload` through a fresh service; returns
/// (req/s, p50 ms, p99 ms, shed).
fn run_load(cfg: ServiceConfig, workload: Vec<(Measure, Measure)>) -> (f64, f64, f64, u64) {
    let n_req = workload.len();
    let svc = Service::start(cfg).expect("service start");
    let h = svc.handle();
    let sw = Stopwatch::start();
    let mut pendings = Vec::with_capacity(n_req);
    for (mu, nu) in workload {
        if let Ok(p) = h.submit(mu, nu) {
            pendings.push(p);
        }
    }
    let mut latencies: Vec<u64> = Vec::new();
    let mut shed = n_req - pendings.len();
    for p in pendings {
        match p.wait() {
            Ok(resp) => latencies.push(resp.latency_us),
            Err(_) => shed += 1,
        }
    }
    let total = sw.elapsed_secs();
    latencies.sort_unstable();
    let q = |f: f64| -> f64 {
        if latencies.is_empty() {
            return 0.0;
        }
        latencies[((latencies.len() - 1) as f64 * f) as usize] as f64 / 1e3
    };
    drop(h);
    svc.shutdown();
    (latencies.len() as f64 / total, q(0.5), q(0.99), shed as u64)
}

/// Mixed workload: per-request clouds (nothing fuses).
fn mixed_workload(n_req: usize, n: usize) -> Vec<(Measure, Measure)> {
    let mut rng = Rng::seed_from(1);
    (0..n_req).map(|_| data::gaussian_blobs(n, &mut rng)).collect()
}

/// Shared-support workload: one cloud pair, per-request weight skews —
/// every request is fusable with every other.
fn shared_workload(n_req: usize, n: usize) -> Vec<(Measure, Measure)> {
    let mut rng = Rng::seed_from(2);
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    (0..n_req)
        .map(|k| {
            let reweight = |base: &Measure, salt: usize| {
                let raw: Vec<f64> = (0..base.len())
                    .map(|i| 1.0 + ((i * (salt + 2) + salt) % 11) as f64 * 0.1)
                    .collect();
                let total: f64 = raw.iter().sum();
                let mut m = base.clone();
                m.weights = raw.iter().map(|&x| (x / total) as f32).collect();
                m
            };
            (reweight(&mu, k), reweight(&nu, k + 1))
        })
        .collect()
}

fn main() {
    let args = ArgSpec::new("coord", "divergence service throughput/latency")
        .opt("requests", "64", "requests per configuration")
        .opt("n", "400", "samples per cloud")
        .opt("csv", "target/coordinator.csv", "csv output (mixed workload)")
        .opt(
            "batched-csv",
            "target/coordinator_batched.csv",
            "csv output (batched-vs-sequential table)",
        )
        .opt(
            "sharded-csv",
            "target/coordinator_sharded.csv",
            "csv output (sharded multi-worker table)",
        )
        .parse();
    let smoke = std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0");
    let (n_req, n) = if smoke {
        println!("(BENCH_SMOKE: reduced sizes)");
        (24, 120)
    } else {
        (args.get_usize("requests"), args.get_usize("n"))
    };
    let worker_counts: &[usize] = if smoke { &[2] } else { &[1, 2, 4, 8] };

    let mut t = Table::new(
        "Coordinator throughput (open-loop burst, mixed workload)",
        &["workers", "max_batch", "req/s", "p50 ms", "p99 ms", "shed"],
    );
    for &workers in worker_counts {
        for &mb in &[1usize, 8, 32] {
            let (rps, p50, p99, shed) =
                run_load(service_cfg(workers, mb, 8), mixed_workload(n_req, n));
            t.row(vec![
                workers.to_string(),
                mb.to_string(),
                format!("{rps:.1}"),
                format!("{p50:.1}"),
                format!("{p99:.1}"),
                shed.to_string(),
            ]);
        }
    }
    t.emit(Some(args.get_str("csv")));

    // Batched vs sequential: same shared-support workload, fuse width
    // swept with 1 as the sequential baseline. Throughput (req/s) is the
    // figure of merit; the fused case amortises one kernel triple and the
    // factor streams across the whole group.
    let fuse_widths: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8, 16] };
    let mut bt = Table::new(
        "Batched vs sequential solves (shared-support workload)",
        &["workers", "fuse width", "req/s", "p50 ms", "p99 ms", "speedup vs width 1"],
    );
    for &workers in worker_counts {
        let mut base_rps = 0.0f64;
        for &width in fuse_widths {
            let cfg = service_cfg(workers, 32, width);
            let (rps, p50, p99, _) = run_load(cfg, shared_workload(n_req, n));
            if width == 1 {
                base_rps = rps;
            }
            bt.row(vec![
                workers.to_string(),
                width.to_string(),
                format!("{rps:.1}"),
                format!("{p50:.1}"),
                format!("{p99:.1}"),
                format!("{:.2}x", rps / base_rps.max(1e-9)),
            ]);
        }
    }
    bt.emit(Some(args.get_str("batched-csv")));

    // Sharded serving: the same fusable workload with every fuse group
    // delegated through the shard coordinator's wire-format
    // scatter/gather path. `0` shard workers is the in-process baseline;
    // the delta at 1 worker is pure envelope + transport overhead, and
    // higher counts measure scatter parallelism across chunked groups.
    let shard_counts: &[usize] = if smoke { &[0, 2] } else { &[0, 1, 2, 4] };
    let mut st = Table::new(
        "Sharded serving (shared-support workload, fuse width 8)",
        &["shard workers", "req/s", "p50 ms", "p99 ms", "speedup vs in-process"],
    );
    let mut shard_base_rps = 0.0f64;
    for &shards in shard_counts {
        let mut cfg = service_cfg(2, 32, 8);
        cfg.shard_workers = shards;
        let (rps, p50, p99, _) = run_load(cfg, shared_workload(n_req, n));
        if shards == 0 {
            shard_base_rps = rps;
        }
        st.row(vec![
            shards.to_string(),
            format!("{rps:.1}"),
            format!("{p50:.1}"),
            format!("{p99:.1}"),
            format!("{:.2}x", rps / shard_base_rps.max(1e-9)),
        ]);
    }
    st.emit(Some(args.get_str("sharded-csv")));

    println!(
        "\nacceptance bar: shared-support req/s at fuse width >= 4 beats width 1 \
         (EXPERIMENTS.md §Throughput)"
    );
}
