//! Theorem 3.1's headline claim: Sinkhorn iterations cost O(r(n+m)) with
//! the factored kernel vs O(nm) dense. Measures per-iteration wall-clock
//! vs n at fixed r for both paths and reports the empirical scaling
//! exponents and the crossover point.
//!
//! Expected shape: RF per-iteration time grows ~linearly in n (slope ~1 on
//! log-log), dense grows ~quadratically (slope ~2); RF wins for n >> r.
//!
//! Run: `cargo bench --bench scaling_linear_time`

use linear_sinkhorn::bench::{fmt_secs, time, Table};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::prelude::*;
// Solver-layer microbench: times the reference free functions directly so
// kernel construction stays outside the measured region (the planned API
// builds kernels inside its execution path).
use linear_sinkhorn::sinkhorn::sinkhorn;

fn main() {
    let args = ArgSpec::new("scaling", "per-iteration scaling: O(r(n+m)) vs O(nm)")
        .opt("sizes", "250,500,1000,2000,4000,8000", "values of n to sweep")
        .opt("features", "400", "fixed feature count r")
        .opt("iters", "10", "iterations to time per measurement")
        .opt("seed", "0", "seed")
        .opt("csv", "target/scaling.csv", "csv output")
        .parse();

    let sizes = args.get_usize_list("sizes");
    let r = args.get_usize("features");
    let iters = args.get_usize("iters");
    let eps = 0.5;
    let mut rng = Rng::seed_from(args.get_u64("seed"));

    let cfg = SinkhornConfig {
        epsilon: eps,
        max_iters: iters,
        tol: 0.0,
        check_every: iters + 1,
        ..Default::default()
    };
    let mut t = Table::new(
        "Per-iteration scaling (fixed r, growing n)",
        &["n", "RF time/iter", "Sin time/iter", "RF flops/apply", "Sin flops/apply", "speedup"],
    );
    let mut rf_pts = Vec::new();
    let mut sin_pts = Vec::new();

    for &n in &sizes {
        let (mu, nu) = data::gaussian_blobs(n, &mut rng);
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, r, &mut rng);
        let fk = FactoredKernel::from_measures(&map, &mu, &nu);
        let rf = time(1, 3, || {
            let _ = sinkhorn(&fk, &mu.weights, &nu.weights, &cfg).unwrap();
        });
        let rf_iter = rf.median_s / iters as f64;
        rf_pts.push((n as f64, rf_iter));

        // Dense path: skip the largest sizes if they would take minutes.
        let (sin_iter, sin_flops, speedup) = if n <= 8000 {
            let dk = DenseKernel::from_measures(&mu, &nu, eps);
            let sin = time(1, 3, || {
                let _ = sinkhorn(&dk, &mu.weights, &nu.weights, &cfg).unwrap();
            });
            let s = sin.median_s / iters as f64;
            sin_pts.push((n as f64, s));
            (fmt_secs(s), dk.flops_per_apply().to_string(), format!("{:.1}x", s / rf_iter))
        } else {
            ("skipped".into(), "-".into(), "-".into())
        };
        t.row(vec![
            n.to_string(),
            fmt_secs(rf_iter),
            sin_iter,
            fk.flops_per_apply().to_string(),
            sin_flops,
            speedup,
        ]);
    }
    t.emit(Some(args.get_str("csv")));

    // Log-log slope fits.
    let slope = |pts: &[(f64, f64)]| -> f64 {
        let n = pts.len() as f64;
        let (sx, sy, sxx, sxy) = pts.iter().fold((0.0, 0.0, 0.0, 0.0), |(a, b, c, d), &(x, y)| {
            let (lx, ly) = (x.ln(), y.ln());
            (a + lx, b + ly, c + lx * lx, d + lx * ly)
        });
        (n * sxy - sx * sy) / (n * sxx - sx * sx)
    };
    println!(
        "empirical scaling exponents: RF {:.2} (expect ~1), Sin {:.2} (expect ~2)",
        slope(&rf_pts),
        slope(&sin_pts)
    );
}
