//! Remark 2 / Theorem A.2: accelerated Sinkhorn (Alg. 2) combined with the
//! factored kernel. Compares iterations-to-tolerance and wall-clock of
//! Alg. 1 vs Alg. 2 on the Fig-1 workload across regularisations.
//!
//! Expected shape: acceleration pays off at small eps (Alg. 1's iteration
//! count blows up as ~1/eps while Alg. 2 scales as ~sqrt(1/eps) in theory).
//!
//! Run: `cargo bench --bench accelerated_sinkhorn`

use linear_sinkhorn::bench::{fmt_secs, Table};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;
// Solver-layer microbench: times the reference free functions directly so
// the shared kernel build stays outside the measured region.
use linear_sinkhorn::sinkhorn::{sinkhorn, sinkhorn_accelerated};

fn main() {
    let args = ArgSpec::new("accel", "Alg.1 vs Alg.2 on the factored kernel")
        .opt("n", "1000", "samples per cloud")
        .opt("features", "400", "feature count r")
        .opt("eps", "0.05,0.1,0.25,0.5,1.0", "regularisations")
        .opt("seed", "0", "seed")
        .opt("csv", "target/accel.csv", "csv output")
        .parse();

    let n = args.get_usize("n");
    let r = args.get_usize("features");
    let mut rng = Rng::seed_from(args.get_u64("seed"));
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);

    let mut t = Table::new(
        "Accelerated Sinkhorn (Alg. 2) vs Alg. 1, factored kernel",
        &["eps", "alg1 iters", "alg1 time", "alg1 obj", "alg2 iters", "alg2 time", "alg2 obj"],
    );

    for eps in args.get_f64_list("eps") {
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, r, &mut rng);
        let fk = FactoredKernel::from_measures(&map, &mu, &nu);
        // Matched stopping criteria: Alg.1 stops on L1 marginal error, Alg.2
        // on the dual gradient norm — both set to the same delta.
        let delta = 1e-5;
        let cfg1 = SinkhornConfig {
            epsilon: eps,
            max_iters: 100_000,
            tol: delta,
            check_every: 5,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let s1 = sinkhorn(&fk, &mu.weights, &nu.weights, &cfg1);
        let t1 = sw.elapsed_secs();
        let cfg2 = SinkhornConfig {
            epsilon: eps,
            max_iters: 50_000,
            tol: delta,
            check_every: 1,
            ..Default::default()
        };
        let sw = Stopwatch::start();
        let s2 = sinkhorn_accelerated(&fk, &mu.weights, &nu.weights, &cfg2);
        let t2 = sw.elapsed_secs();
        let (i1, o1) = match &s1 {
            Ok(s) => (s.iterations.to_string(), format!("{:.5}", s.objective)),
            Err(e) => (format!("FAIL({e:.20})"), "-".into()),
        };
        let (i2, o2) = match &s2 {
            Ok(s) => (s.iterations.to_string(), format!("{:.5}", s.objective)),
            Err(e) => (format!("FAIL({e:.20})"), "-".into()),
        };
        t.row(vec![
            format!("{eps}"),
            i1,
            fmt_secs(t1),
            o1,
            i2,
            fmt_secs(t2),
            o2,
        ]);
    }
    t.emit(Some(args.get_str("csv")));
}
