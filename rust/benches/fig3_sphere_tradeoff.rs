//! Figure 3: time–accuracy tradeoff on two uniform distributions on the
//! unit sphere S^2 (Figure 2's red/blue bands). Paper: n = 20000, 10 reps,
//! eps in {0.01, 0.05, 0.1, 0.5}; default here n = 1500 / 3 reps.
//!
//! Expected shape: Nys fails at the three smaller regularisations while RF
//! works at any r; both fast and accurate at eps = 0.5.
//!
//! Run: `cargo bench --bench fig3_sphere_tradeoff [-- --full --dump-data]`

use linear_sinkhorn::bench::tradeoff::{cells_to_table, run_sweep, Sweep};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::prelude::*;

fn main() {
    let args = ArgSpec::new("fig3", "Fig.3 sphere time-accuracy tradeoff")
        .opt("n", "1500", "samples per cloud")
        .opt("reps", "3", "repetitions per cell")
        .opt("eps", "0.01,0.05,0.1,0.5", "regularisations")
        .opt("ranks", "100,300,600,1000,2000", "feature counts / ranks")
        .opt("seed", "0", "seed")
        .opt("csv", "target/fig3.csv", "csv output path")
        .flag("full", "paper-scale n=20000, 10 reps (slow)")
        .flag("dump-data", "also write the Fig.2 point clouds as CSV")
        .parse();

    let (n, reps) = if args.get_flag("full") {
        (20_000, 10)
    } else {
        (args.get_usize("n"), args.get_usize("reps"))
    };
    let mut rng = Rng::seed_from(args.get_u64("seed"));
    let (mu, nu) = data::sphere_caps(n, &mut rng);
    println!("fig3: n={n} per band, reps={reps} (paper: 20000/10)");

    if args.get_flag("dump-data") {
        // Figure 2: the two sphere point sets.
        let mut csv = String::from("band,x,y,z\n");
        for (label, m) in [("red", &mu), ("blue", &nu)] {
            for i in 0..m.len() {
                let p = m.points.row(i);
                csv.push_str(&format!("{label},{},{},{}\n", p[0], p[1], p[2]));
            }
        }
        std::fs::create_dir_all("target").ok();
        std::fs::write("target/fig2_sphere_points.csv", csv).unwrap();
        println!("Figure 2 point clouds written to target/fig2_sphere_points.csv");
    }

    let sweep = Sweep {
        epsilons: args.get_f64_list("eps"),
        ranks: args.get_usize_list("ranks"),
        reps,
        ..Default::default()
    };
    let cells = run_sweep(&mu, &nu, &sweep, args.get_u64("seed"), |c| {
        eprintln!(
            "  {} eps={} r={} -> dev {}",
            c.method,
            c.eps,
            c.rank,
            if c.deviation.is_nan() { "FAILED".into() } else { format!("{:.2}", c.deviation) }
        );
    });
    cells_to_table("Figure 3 — sphere bands time–accuracy tradeoff", &cells)
        .emit(Some(args.get_str("csv")));
}
