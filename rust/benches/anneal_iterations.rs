//! Annealing iteration-count bench: direct vs annealed vs
//! annealed+symmetric divergence solves at decreasing target eps.
//!
//! The EXPERIMENTS.md §Annealing anchor: per target eps the table
//! records, for the three-solve divergence on the same clouds,
//!   * `direct`   — one solve pinned at the target eps (the planner's
//!     automatic domain choice, log-domain at tiny eps),
//!   * `anneal`   — the geometric eps schedule with dual warm starts
//!     between rungs, two-sided self solves, and
//!   * `anneal+sym` — the schedule plus the one-dual symmetric fixed
//!     point for the xx/yy self solves,
//! along with total iteration counts (all rungs, all three solves), rung
//! counts, wall clock, and the relative deviation of each annealed
//! divergence from the direct one (they solve the *same* problem — the
//! schedule only changes the path to the target rung).
//!
//! The acceptance bar is >= 3x total-iteration reduction for
//! `anneal+sym` vs `direct` at eps = 1e-3 (n = 1e4, r = 128) with the
//! divergences in tolerance-level agreement.
//!
//! Run: `cargo bench --bench anneal_iterations`
//!
//! Setting `BENCH_SMOKE=1` overrides every size knob with CI-scale values
//! (the `bench-smoke` job's quick mode); setting `BENCH_JSON=<path>`
//! additionally appends the table there in JSON-lines form (see
//! `bench::Table::emit`).

use linear_sinkhorn::bench::{fmt_secs, Table};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

/// One measured variant: plan + divergence, returning the report and the
/// end-to-end wall clock (kernel construction included — annealing pays
/// a per-rung rebuild, and that cost belongs in the table).
fn run_variant(
    mu: &Measure,
    nu: &Measure,
    eps: f64,
    r: usize,
    max_iters: usize,
    seed: u64,
    anneal: bool,
    symmetric: bool,
) -> Result<(DivergenceReport, f64)> {
    let sw = Stopwatch::start();
    let report = OtProblem::new(mu, nu)
        .epsilon(eps)
        .rank(r)
        .max_iters(max_iters)
        .seed(seed)
        .anneal(anneal)
        .symmetric_self_solves(symmetric)
        .divergence()?;
    Ok((report, sw.elapsed_secs()))
}

fn main() {
    let args = ArgSpec::new(
        "anneal_iterations",
        "direct vs annealed vs annealed+symmetric iteration counts",
    )
    .opt("n", "10000", "samples per cloud")
    .opt("features", "128", "positive random features r")
    .opt("eps", "0.1,0.01,0.001", "target eps values to sweep")
    .opt("max-iters", "20000", "iteration cap per solve")
    .opt("seed", "0", "RNG seed")
    .opt("csv", "target/anneal_iterations.csv", "csv output")
    .parse();

    // CI quick mode: small clouds, moderate eps — enough to smoke every
    // annealed path and record an iteration-count trajectory point.
    let smoke = std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0");
    let (n, r, eps_list, max_iters) = if smoke {
        println!("(BENCH_SMOKE: reduced sizes)");
        (600, 48, vec![0.1, 0.02], 4000)
    } else {
        (
            args.get_usize("n"),
            args.get_usize("features"),
            args.get_f64_list("eps"),
            args.get_usize("max-iters"),
        )
    };
    let seed = args.get_u64("seed");
    let mut rng = Rng::seed_from(seed);
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);

    let mut t = Table::new(
        "Annealing iteration counts (three-solve divergence, r fixed)",
        &[
            "eps", "variant", "iters", "rungs", "time", "divergence", "vs direct",
            "iter reduction",
        ],
    );

    for &eps in &eps_list {
        let direct = match run_variant(&mu, &nu, eps, r, max_iters, seed, false, false) {
            Ok(d) => d,
            Err(e) => {
                println!("eps {eps}: direct solve failed: {e}");
                continue;
            }
        };
        let direct_iters = direct.0.total_iterations();
        t.row(vec![
            format!("{eps}"),
            "direct".into(),
            direct_iters.to_string(),
            "1".into(),
            fmt_secs(direct.1),
            format!("{:.6}", direct.0.divergence),
            "-".into(),
            "1.00x".into(),
        ]);
        for (label, symmetric) in [("anneal", false), ("anneal+sym", true)] {
            match run_variant(&mu, &nu, eps, r, max_iters, seed, true, symmetric) {
                Ok((rep, secs)) => {
                    let iters = rep.total_iterations();
                    let scale = direct.0.divergence.abs().max(1e-9);
                    t.row(vec![
                        format!("{eps}"),
                        label.into(),
                        iters.to_string(),
                        rep.xy.rung_iterations.len().to_string(),
                        fmt_secs(secs),
                        format!("{:.6}", rep.divergence),
                        format!(
                            "{:.2e}",
                            (rep.divergence - direct.0.divergence).abs() / scale
                        ),
                        format!("{:.2}x", direct_iters as f64 / iters.max(1) as f64),
                    ]);
                }
                Err(e) => println!("eps {eps}: {label} failed: {e}"),
            }
        }
    }

    t.emit(Some(args.get_str("csv")));
    println!(
        "\nacceptance bar: anneal+sym iter reduction >= 3x vs direct at eps=1e-3 \
         (n=10000, r=128) with `vs direct` at tolerance level \
         (EXPERIMENTS.md §Annealing)"
    );
}
