//! SIMD-core bench: the scalar arm vs the runtime-dispatched arm on the
//! four hot linalg kernels, single thread (EXPERIMENTS.md §Perf,
//! "SIMD core").
//!
//! The acceptance bar for the SIMD execution layer is **≥ 2x on
//! `matvec_into` and ≥ 3x on `lse_matvec_into` at n = 10^4, r = 128**
//! (single thread, AVX2+FMA vs scalar). Both arms are timed in one
//! process through the `*_at` kernel entry points, so the table is a
//! genuine before/after on identical buffers; the `cpu` field of the
//! recorded JSON names the dispatched arm (`scalar` on machines without
//! AVX2+FMA, where the speedup column reads ~1.00x by construction).
//!
//! Run: `cargo bench --bench simd_kernels`
//!
//! Setting `BENCH_SMOKE=1` only trims repetitions (the n = 10^4 problem
//! is already CI-scale); setting `BENCH_JSON=<path>` appends the table
//! to that file in JSON-lines form (see `bench::Table::emit`) — the CI
//! `bench-smoke` job records it into `BENCH_ci.json` on every push.

use linear_sinkhorn::bench::{fmt_secs, time, Table};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::linalg::simd::{active_level, SimdLevel};
use linear_sinkhorn::linalg::{
    lse_matvec_into_at, lse_matvec_t_into_at, matvec_into_at, matvec_t_into_at, Mat,
};
use linear_sinkhorn::rng::Rng;

fn main() {
    let args = ArgSpec::new("simd_kernels", "scalar vs dispatched SIMD arm, single thread")
        .opt("n", "10000", "row count of the factor matrix")
        .opt("features", "128", "feature count r (columns)")
        .opt("reps", "30", "measured repetitions per cell")
        .opt("seed", "0", "RNG seed")
        .opt("csv", "target/simd_kernels.csv", "csv output")
        .parse();

    let smoke = std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0");
    let (n, r, reps) = if smoke {
        println!("(BENCH_SMOKE: reduced reps)");
        (args.get_usize("n"), args.get_usize("features"), 5)
    } else {
        (args.get_usize("n"), args.get_usize("features"), args.get_usize("reps"))
    };

    let dispatched = active_level();
    let mut rng = Rng::seed_from(args.get_u64("seed"));
    // Positive factor-scale entries — the Sinkhorn regime.
    let a = Mat::from_fn(n, r, |_, _| rng.uniform_in(0.05, 1.0) as f32);
    let v: Vec<f32> = (0..r).map(|_| rng.uniform_in(0.05, 1.0) as f32).collect();
    let u: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.05, 1.0) as f32).collect();
    let t: Vec<f64> = (0..r).map(|_| rng.uniform_in(-30.0, 5.0)).collect();
    let w: Vec<f64> = (0..n).map(|_| rng.uniform_in(-30.0, 5.0)).collect();
    let alpha = -2.0;

    let mut out_n = vec![0.0f32; n];
    let mut out_r = vec![0.0f32; r];
    let mut lout_n = vec![0.0f64; n];
    let mut lout_r = vec![0.0f64; r];

    let mut table = Table::new(
        "simd_kernels (single thread, scalar arm vs dispatched arm)",
        &["kernel", "n", "r", "scalar", "dispatched", "speedup", "arm"],
    );
    let mut record = |kernel: &str, scalar_s: f64, simd_s: f64| {
        table.row(vec![
            kernel.to_string(),
            n.to_string(),
            r.to_string(),
            fmt_secs(scalar_s),
            fmt_secs(simd_s),
            format!("{:.2}x", scalar_s / simd_s),
            dispatched.label().to_string(),
        ]);
    };

    // matvec: out = a @ v (n x r · r).
    let scalar = time(3, reps, || matvec_into_at(SimdLevel::Scalar, &a, &v, &mut out_n)).median_s;
    let simd = time(3, reps, || matvec_into_at(dispatched, &a, &v, &mut out_n)).median_s;
    record("matvec_into", scalar, simd);

    // matvec_t: out = a^T @ u (r outputs, 8x8 microkernel on AVX2).
    let scalar = time(3, reps, || matvec_t_into_at(SimdLevel::Scalar, &a, &u, &mut out_r)).median_s;
    let simd = time(3, reps, || matvec_t_into_at(dispatched, &a, &u, &mut out_r)).median_s;
    record("matvec_t_into", scalar, simd);

    // lse_matvec: the log-domain row update (one f64 exp per entry).
    let scalar = time(2, reps, || {
        lse_matvec_into_at(SimdLevel::Scalar, &a, alpha, &t, &mut lout_n);
    })
    .median_s;
    let simd = time(2, reps, || {
        lse_matvec_into_at(dispatched, &a, alpha, &t, &mut lout_n);
    })
    .median_s;
    record("lse_matvec_into", scalar, simd);

    // lse_matvec_t: the transposed (column) log-domain update.
    let scalar =
        time(2, reps, || lse_matvec_t_into_at(SimdLevel::Scalar, &a, alpha, &w, &mut lout_r))
            .median_s;
    let simd =
        time(2, reps, || lse_matvec_t_into_at(dispatched, &a, alpha, &w, &mut lout_r)).median_s;
    record("lse_matvec_t_into", scalar, simd);

    table.emit(Some(args.get_str("csv")));

    println!(
        "\ndispatched arm: {} (force the fallback with LINEAR_SINKHORN_SIMD=scalar)\n\
         acceptance bar: >=2x on matvec_into and >=3x on lse_matvec_into at n=10^4, r=128 \
         (EXPERIMENTS.md §Perf, \"SIMD core\")",
        dispatched.label()
    );
}
