//! Table 1: the learned adversarial kernel k_theta(f_gamma(x), f_gamma(z))
//! evaluated between images and noise after GAN training — the kernel
//! should capture the image-manifold structure: k(image, image) >>
//! k(image, noise) >> or >> k(noise, noise).
//!
//! Paper: trained 84h on CIFAR-10 (Tesla K80); here: the synthetic image
//! corpus and a few hundred CPU steps (see EXPERIMENTS.md §GAN training
//! runs) — the *ordering* and
//! the large ii/in ratio are the claims under test. Values are averages
//! over 5x5 sample pairs exactly as in the paper.
//!
//! Run: `cargo bench --bench table1_learned_kernel [-- --steps 300]`

use linear_sinkhorn::bench::Table;
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::config::GanConfig;
use linear_sinkhorn::gan::GanTrainer;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

fn main() {
    let args = ArgSpec::new("table1", "Table 1 learned-kernel probe")
        .opt("steps", "200", "generator steps to train")
        .opt("batch", "128", "minibatch size")
        .opt("features", "64", "learned feature count r (paper: 600)")
        .opt("side", "8", "image side")
        .opt("seed", "0", "seed")
        .opt("csv", "target/table1.csv", "csv output")
        .parse();

    let side = args.get_usize("side");
    let dim = side * side;
    let cfg = GanConfig {
        steps: args.get_usize("steps"),
        batch_size: args.get_usize("batch"),
        num_features: args.get_usize("features"),
        epsilon: 1.0,
        seed: args.get_u64("seed"),
        ..Default::default()
    };
    let mut rng = Rng::seed_from(cfg.seed);
    let corpus = data::image_corpus(cfg.batch_size * 6, side, &mut rng);
    let mut trainer = GanTrainer::new(dim, cfg.clone(), &mut rng);
    let mut batch_rng = Rng::seed_from(cfg.seed ^ 0xABCD);

    println!("training {} steps (batch {}, r {}) …", cfg.steps, cfg.batch_size, cfg.num_features);
    let sw = Stopwatch::start();
    for step in 0..cfg.steps {
        let idx = batch_rng.sample_indices(corpus.rows(), cfg.batch_size);
        let real = Mat::from_fn(cfg.batch_size, dim, |i, j| corpus[(idx[i], j)]);
        trainer.train_step(step, &real).expect("train step");
    }
    println!("trained in {:.1}s", sw.elapsed_secs());

    // Table 1 probe: 5 held-out images, 5 noise samples.
    let mut probe_rng = Rng::seed_from(4242);
    let imgs = data::image_corpus(5, side, &mut probe_rng);
    let noise = data::noise_images(5, side, &mut probe_rng);
    let k_ii = trainer.mean_kernel(&imgs, &imgs);
    let k_in = trainer.mean_kernel(&imgs, &noise);
    let k_nn = trainer.mean_kernel(&noise, &noise);

    let mut t = Table::new(
        "Table 1 — learned kernel k_theta(f_gamma(.), f_gamma(.)), 5x5 averages",
        &["", "image x", "noise z"],
    );
    t.row(vec!["image x".into(), format!("{k_ii:.4e}"), format!("{k_in:.4e}")]);
    t.row(vec!["noise z".into(), format!("{k_in:.4e}"), format!("{k_nn:.4e}")]);
    t.emit(Some(args.get_str("csv")));

    println!(
        "ordering: k_ii {} k_in, ratio k_ii/k_in = {:.2} (paper: 1802e12 vs 2961e5, ratio ~6e6)",
        if k_ii > k_in { ">" } else { "<= (UNEXPECTED)" },
        k_ii / k_in.max(1e-300)
    );
}
