//! Figure 5 (appendix): time–accuracy tradeoff in the high-dimensional
//! regime — 28-dim HIGGS-like two-class data (synthetic substitute with
//! the dataset's dimension and class structure; see the `higgs_like`
//! rustdoc in `rust/src/data/`). Paper: 2 x 5000 samples, 10 reps,
//! eps in {1, 5, 10, 15} (the high-dim regime needs larger eps because
//! squared distances concentrate around 2d).
//!
//! Expected shape: at the larger eps both Nys and RF are fast+accurate
//! (Nys somewhat better in high dim); at the smallest eps both degrade.
//!
//! Run: `cargo bench --bench fig5_higgs_tradeoff [-- --full]`

use linear_sinkhorn::bench::tradeoff::{cells_to_table, run_sweep, Sweep};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::prelude::*;

fn main() {
    let args = ArgSpec::new("fig5", "Fig.5 Higgs-like high-dim tradeoff")
        .opt("n", "1000", "samples per class")
        .opt("reps", "3", "repetitions per cell")
        .opt("eps", "1.0,5.0,10.0,15.0", "regularisations")
        .opt("ranks", "100,300,600,1000", "feature counts / ranks")
        .opt("seed", "0", "seed")
        .opt("csv", "target/fig5.csv", "csv output path")
        .flag("full", "paper-scale n=5000, 10 reps (slow)")
        .parse();

    let (n, reps) = if args.get_flag("full") {
        (5_000, 10)
    } else {
        (args.get_usize("n"), args.get_usize("reps"))
    };
    let mut rng = Rng::seed_from(args.get_u64("seed"));
    let (sig, bkg) = data::higgs_pair(n, &mut rng);
    println!("fig5: n={n} per class, d=28, reps={reps} (paper: 5000/10 on real HIGGS)");

    let sweep = Sweep {
        epsilons: args.get_f64_list("eps"),
        ranks: args.get_usize_list("ranks"),
        reps,
        ..Default::default()
    };
    let cells = run_sweep(&sig, &bkg, &sweep, args.get_u64("seed"), |c| {
        eprintln!(
            "  {} eps={} r={} -> dev {}",
            c.method,
            c.eps,
            c.rank,
            if c.deviation.is_nan() { "FAILED".into() } else { format!("{:.2}", c.deviation) }
        );
    });
    cells_to_table("Figure 5 — Higgs-like high-dimensional tradeoff", &cells)
        .emit(Some(args.get_str("csv")));
}
