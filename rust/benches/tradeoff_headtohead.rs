//! PR-8 head-to-head: the paper's positive features vs adaptive Nyström
//! vs uniform Nyström at one matched rank, error vs time across
//! eps ∈ {1e-1, 1e-2, 1e-3}.
//!
//! Expected shape: at eps = 1e-1 all three answer and the Nyström arms
//! are competitive (adaptive at or below uniform's error — spread
//! landmarks cover the union cloud better at the same rank); at
//! eps ∈ {1e-2, 1e-3} the Gibbs kernel's numerical rank explodes,
//! Nyström loses positivity and both arms record FAILED (the clamped
//! signed log view gates itself off, so escalation fails typed instead
//! of converging wrong), while the positive-feature kernel escalates to
//! the log domain and still answers — the paper's central contrast,
//! measured end to end through the planned API.
//!
//! Run: `cargo bench --bench tradeoff_headtohead`
//!
//! Setting `BENCH_SMOKE=1` shrinks the clouds and repetitions to CI
//! scale (the eps sweep is untouched — the contrast is the point);
//! `BENCH_JSON=<path>` appends the table there as JSON lines (the CI
//! `bench-smoke` job records it into `BENCH_ci.json` on every push).

use linear_sinkhorn::bench::tradeoff::{cells_to_table, run_headtohead};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::prelude::*;

fn main() {
    let args = ArgSpec::new("tradeoff_headtohead", "RF vs adaptive vs uniform Nyström")
        .opt("n", "1000", "samples per cloud")
        .opt("rank", "64", "matched rank: feature count r = landmark count")
        .opt("eps", "0.1,0.01,0.001", "regularisations")
        .opt("reps", "3", "repetitions per cell")
        .opt("seed", "0", "RNG seed")
        .opt("csv", "target/tradeoff_headtohead.csv", "csv output path")
        .parse();

    let smoke = std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0");
    let (n, rank, reps) = if smoke {
        println!("(BENCH_SMOKE: reduced sizes)");
        (200, 32, 1)
    } else {
        (args.get_usize("n"), args.get_usize("rank"), args.get_usize("reps"))
    };
    let epsilons = args.get_f64_list("eps");
    let seed = args.get_u64("seed");
    let mut rng = Rng::seed_from(seed);
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    println!("tradeoff_headtohead: n={n}, rank={rank}, reps={reps}, eps={epsilons:?}");

    let cells = run_headtohead(&mu, &nu, &epsilons, rank, reps, seed, |c| {
        eprintln!(
            "  {:<5} eps={} r={} -> dev {} in {} ({}/{})",
            c.method,
            c.eps,
            c.rank,
            if c.deviation.is_nan() { "FAILED".into() } else { format!("{:.2}", c.deviation) },
            if c.time_s.is_nan() { "-".into() } else { format!("{:.3}s", c.time_s) },
            c.ok,
            c.reps
        );
    });
    cells_to_table("Tradeoff head-to-head — RF vs Nys+a vs Nys at matched rank", &cells)
        .emit(Some(args.get_str("csv")));
}
