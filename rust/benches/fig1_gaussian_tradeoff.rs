//! Figure 1: time–accuracy tradeoff on two 2-D Gaussians
//! (N((1,1), I2) vs N(0, 0.1 I2)), RF vs Nys vs Sin across
//! regularisations and feature counts.
//!
//! Paper setup: n = 40000 samples, 50 repetitions. Default here is a
//! laptop-scale n = 2000 / 3 reps (the complexity contrast is identical);
//! pass `--full` for the paper's sizes.
//!
//! Expected shape (paper): at eps in {0.5, 1} both RF and Nys reach ~100
//! deviation orders of magnitude faster than Sin; at eps in {0.1, 0.05}
//! Nys FAILS (positivity) while RF still returns ~100±few; at very small
//! eps RF degrades to ~10% error.
//!
//! Run: `cargo bench --bench fig1_gaussian_tradeoff [-- --full]`

use linear_sinkhorn::bench::tradeoff::{cells_to_table, run_sweep, Sweep};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::prelude::*;

fn main() {
    let args = ArgSpec::new("fig1", "Fig.1 Gaussian time-accuracy tradeoff")
        .opt("n", "2000", "samples per cloud")
        .opt("reps", "3", "repetitions per cell")
        .opt("eps", "0.05,0.1,0.5,1.0,2.0", "regularisations")
        .opt("ranks", "100,300,600,1000,2000", "feature counts / ranks")
        .opt("seed", "0", "seed")
        .opt("csv", "target/fig1.csv", "csv output path")
        .flag("full", "paper-scale n=40000, 50 reps (slow)")
        .parse();

    let (n, reps) = if args.get_flag("full") {
        (40_000, 50)
    } else {
        (args.get_usize("n"), args.get_usize("reps"))
    };
    let mut rng = Rng::seed_from(args.get_u64("seed"));
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    println!("fig1: n={n}, reps={reps} (paper: 40000/50)");

    let sweep = Sweep {
        epsilons: args.get_f64_list("eps"),
        ranks: args.get_usize_list("ranks"),
        reps,
        ..Default::default()
    };
    let cells = run_sweep(&mu, &nu, &sweep, args.get_u64("seed"), |c| {
        eprintln!(
            "  {} eps={} r={} -> dev {} ({}/{})",
            c.method,
            c.eps,
            c.rank,
            if c.deviation.is_nan() { "FAILED".into() } else { format!("{:.2}", c.deviation) },
            c.ok,
            c.reps
        );
    });
    cells_to_table("Figure 1 — Gaussian blobs time–accuracy tradeoff", &cells)
        .emit(Some(args.get_str("csv")));
}
