//! Parallel-scaling bench: wall-clock of the intra-solve execution layer
//! (`runtime::pool` + `_pooled` matvecs + concurrent three-problem
//! divergence) against the serial path, at n in {1e3, 1e4, 1e5}.
//!
//! Reports, per (n, threads):
//!   * per-apply time of the factored kernel's two matvecs (the entire
//!     Sinkhorn iteration cost), serial vs pooled, and
//!   * a full `sinkhorn_divergence` solve at the paper's O(r(n+m))
//!     complexity, `threads = 1` vs `threads = T` (three concurrent
//!     solves with pooled matvecs inside each).
//!
//! A second table isolates **region dispatch overhead**: the persistent
//! channel-fed pool vs the historical per-region scoped spawning
//! (reimplemented locally below), timed on the pooled transposed matvec
//! at n in {1e2, 1e3, 1e4}. Small n is where the difference lives — the
//! region's compute shrinks toward the dispatch cost (ROADMAP item;
//! results feed EXPERIMENTS.md §Parallel scaling).
//!
//! The acceptance bar for this layer is >1.5x end-to-end at n = 1e4 with
//! 4 threads; results feed EXPERIMENTS.md §Parallel scaling.
//!
//! Run: `cargo bench --bench parallel_scaling`
//! (add `--sizes 1000,10000,100000` to sweep the full range)
//!
//! Setting `BENCH_SMOKE=1` overrides every size knob with CI-scale values
//! (the `bench-smoke` job's quick mode); setting `BENCH_JSON=<path>`
//! additionally appends every table to that file in JSON-lines form (see
//! `bench::Table::emit`).

use std::sync::Mutex;

use linear_sinkhorn::bench::{fmt_secs, time, Table};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::linalg::{
    matvec_into, matvec_into_pooled, matvec_t_into, matvec_t_into_pooled, Mat,
};
use linear_sinkhorn::prelude::*;
// Solver-layer microbench: times the reference free-function divergence on
// prebuilt kernels so kernel construction stays outside the measured region.
use linear_sinkhorn::sinkhorn::sinkhorn_divergence;

/// The pre-persistent-pool execution strategy, verbatim: spawn `threads`
/// scoped workers per region, drain a shared queue, join. Kept here (not
/// in the library) purely as the bench baseline for dispatch overhead.
fn scoped_run_tasks<T: Send, F: Fn(T) + Sync>(threads: usize, tasks: Vec<T>, f: F) {
    let workers = threads.min(tasks.len());
    if workers <= 1 {
        for task in tasks {
            f(task);
        }
        return;
    }
    let queue = Mutex::new(tasks.into_iter());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let task = {
                    let mut q = queue.lock().unwrap();
                    q.next()
                };
                match task {
                    Some(t) => f(t),
                    None => break,
                }
            });
        }
    });
}

/// The per-chunk compute both dispatch arms share: accumulate
/// `a[lo..hi]^T v[lo..hi]` into `buf` row-saxpy style. Identical closure
/// under both strategies, so the measured difference is pure dispatch.
fn chunk_saxpy(a: &Mat, v: &[f32], lo: usize, hi: usize, buf: &mut [f32]) {
    for i in lo..hi {
        let vi = v[i];
        for (o, &x) in buf.iter_mut().zip(a.row(i)) {
            *o += x * vi;
        }
    }
}

fn main() {
    let args = ArgSpec::new("parallel_scaling", "pooled vs serial hot paths")
        .opt("sizes", "1000,10000", "values of n to sweep")
        .opt("threads", "2,4", "pool sizes to compare against serial")
        .opt("spawn-sizes", "100,1000,10000", "n values for the dispatch-overhead case")
        .opt("features", "256", "feature count r")
        .opt("iters", "40", "Sinkhorn iterations per divergence measurement")
        .opt("reps", "3", "measured repetitions per cell")
        .opt("seed", "0", "RNG seed")
        .opt("csv", "target/parallel_scaling.csv", "csv output")
        .parse();

    // CI quick mode: small sizes, few reps — enough to smoke the paths
    // and record a trajectory point, cheap enough for every push.
    let smoke = std::env::var("BENCH_SMOKE").is_ok_and(|v| !v.is_empty() && v != "0");
    let (sizes, thread_counts, spawn_sizes, r, iters, reps) = if smoke {
        println!("(BENCH_SMOKE: reduced sizes)");
        (vec![500, 2000], vec![2], vec![100, 1000], 64, 10, 2)
    } else {
        (
            args.get_usize_list("sizes"),
            args.get_usize_list("threads"),
            args.get_usize_list("spawn-sizes"),
            args.get_usize("features"),
            args.get_usize("iters"),
            args.get_usize("reps"),
        )
    };
    let eps = 0.5;
    let mut rng = Rng::seed_from(args.get_u64("seed"));

    let mut t = Table::new(
        "Parallel scaling (factored kernel, r fixed)",
        &["n", "threads", "matvec/iter serial", "matvec/iter pooled", "mv speedup",
          "divergence serial", "divergence parallel", "div speedup"],
    );

    for &n in &sizes {
        let (mu, nu) = data::gaussian_blobs(n, &mut rng);
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, r, &mut rng);
        let phi_x = map.feature_matrix(&mu.points);
        let phi_y = map.feature_matrix(&nu.points);

        // Serial per-iteration matvec pair (K^T u then K v shapes).
        let v = vec![1.0f32 / n as f32; n];
        let mut mid = vec![0.0f32; r];
        let mut out = vec![0.0f32; n];
        let serial_mv = time(2, reps.max(3) * 3, || {
            matvec_t_into(&phi_y, &v, &mut mid);
            matvec_into(&phi_x, &mid, &mut out);
        })
        .median_s;

        // Serial end-to-end divergence (fixed iteration budget).
        let cfg_serial = SinkhornConfig {
            epsilon: eps,
            max_iters: iters,
            tol: 0.0,
            check_every: iters + 1,
            threads: 1,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        };
        let k_xy = FactoredKernel::from_measures(&map, &mu, &nu);
        let k_xx = FactoredKernel::from_measures(&map, &mu, &mu);
        let k_yy = FactoredKernel::from_measures(&map, &nu, &nu);
        let serial_div = time(1, reps, || {
            sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg_serial)
                .expect("serial divergence");
        })
        .median_s;

        for &threads in &thread_counts {
            let pool = Pool::new(threads);
            let pooled_mv = time(2, reps.max(3) * 3, || {
                matvec_t_into_pooled(&phi_y, &v, &mut mid, &pool);
                matvec_into_pooled(&phi_x, &mid, &mut out, &pool);
            })
            .median_s;

            let cfg_par = SinkhornConfig { threads, ..cfg_serial.clone() };
            let p_xy = FactoredKernel::from_measures_pooled(&map, &mu, &nu, pool.clone());
            let p_xx = FactoredKernel::from_measures_pooled(&map, &mu, &mu, pool.clone());
            let p_yy = FactoredKernel::from_measures_pooled(&map, &nu, &nu, pool);
            let par_div = time(1, reps, || {
                sinkhorn_divergence(&p_xy, &p_xx, &p_yy, &mu.weights, &nu.weights, &cfg_par)
                    .expect("parallel divergence");
            })
            .median_s;

            t.row(vec![
                n.to_string(),
                threads.to_string(),
                fmt_secs(serial_mv),
                fmt_secs(pooled_mv),
                format!("{:.2}x", serial_mv / pooled_mv),
                fmt_secs(serial_div),
                fmt_secs(par_div),
                format!("{:.2}x", serial_div / par_div),
            ]);
        }
    }

    t.emit(Some(args.get_str("csv")));

    // --- Dispatch overhead: persistent pool vs per-region scoped spawn.
    //
    // Both arms run the *same* chunk tasks (row-saxpy over a fixed
    // 256-row grid of an (n, r) factor); one dispatches them with
    // `Pool::run_tasks` on a persistent pool, the other spawns scoped
    // threads per region like the pre-refactor pool did. At small n the
    // region's compute shrinks toward the dispatch cost, which is where
    // the persistent pool earns its keep (ROADMAP item).
    let mut spawn_table = Table::new(
        "Region dispatch overhead (identical chunk tasks, r fixed)",
        &["n", "threads", "scoped spawn/region", "persistent pool/region", "speedup"],
    );
    let spawn_reps = (reps.max(3)) * 10;
    const SPAWN_CHUNK: usize = 256;
    for &n in &spawn_sizes {
        let a = Mat::from_fn(n, r, |i, j| ((i * 31 + j * 7) % 97) as f32 * 0.01 + 0.1);
        let v: Vec<f32> = (0..n).map(|i| 0.5 + (i % 13) as f32 * 0.01).collect();
        let nchunks = n.div_ceil(SPAWN_CHUNK);
        let mut partials: Vec<Vec<f32>> = (0..nchunks).map(|_| vec![0.0f32; r]).collect();
        for &threads in &thread_counts {
            let scoped = time(3, spawn_reps, || {
                let tasks: Vec<(usize, &mut Vec<f32>)> =
                    partials.iter_mut().enumerate().collect();
                scoped_run_tasks(threads, tasks, |(c, buf)| {
                    let lo = c * SPAWN_CHUNK;
                    chunk_saxpy(&a, &v, lo, (lo + SPAWN_CHUNK).min(n), buf);
                });
            })
            .median_s;
            let pool = Pool::new(threads);
            let pooled = time(3, spawn_reps, || {
                let tasks: Vec<(usize, &mut Vec<f32>)> =
                    partials.iter_mut().enumerate().collect();
                pool.run_tasks(tasks, |(c, buf)| {
                    let lo = c * SPAWN_CHUNK;
                    chunk_saxpy(&a, &v, lo, (lo + SPAWN_CHUNK).min(n), buf);
                });
            })
            .median_s;
            spawn_table.row(vec![
                n.to_string(),
                threads.to_string(),
                fmt_secs(scoped),
                fmt_secs(pooled),
                format!("{:.2}x", scoped / pooled),
            ]);
        }
    }
    spawn_table.emit(None);

    println!(
        "\nacceptance bar: divergence speedup > 1.5x at n=10000, threads=4 \
         (EXPERIMENTS.md §Parallel scaling)"
    );
}
