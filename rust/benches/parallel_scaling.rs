//! Parallel-scaling bench: wall-clock of the intra-solve execution layer
//! (`runtime::pool` + `_pooled` matvecs + concurrent three-problem
//! divergence) against the serial path, at n in {1e3, 1e4, 1e5}.
//!
//! Reports, per (n, threads):
//!   * per-apply time of the factored kernel's two matvecs (the entire
//!     Sinkhorn iteration cost), serial vs pooled, and
//!   * a full `sinkhorn_divergence` solve at the paper's O(r(n+m))
//!     complexity, `threads = 1` vs `threads = T` (three concurrent
//!     solves with pooled matvecs inside each).
//!
//! The acceptance bar for this layer is >1.5x end-to-end at n = 1e4 with
//! 4 threads; results feed EXPERIMENTS.md §Parallel scaling.
//!
//! Run: `cargo bench --bench parallel_scaling`
//! (add `--sizes 1000,10000,100000` to sweep the full range)

use linear_sinkhorn::bench::{fmt_secs, time, Table};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::linalg::{matvec_into, matvec_into_pooled, matvec_t_into, matvec_t_into_pooled};
use linear_sinkhorn::prelude::*;

fn main() {
    let args = ArgSpec::new("parallel_scaling", "pooled vs serial hot paths")
        .opt("sizes", "1000,10000", "values of n to sweep")
        .opt("threads", "2,4", "pool sizes to compare against serial")
        .opt("features", "256", "feature count r")
        .opt("iters", "40", "Sinkhorn iterations per divergence measurement")
        .opt("reps", "3", "measured repetitions per cell")
        .opt("seed", "0", "RNG seed")
        .opt("csv", "target/parallel_scaling.csv", "csv output")
        .parse();

    let sizes = args.get_usize_list("sizes");
    let thread_counts = args.get_usize_list("threads");
    let r = args.get_usize("features");
    let iters = args.get_usize("iters");
    let reps = args.get_usize("reps");
    let eps = 0.5;
    let mut rng = Rng::seed_from(args.get_u64("seed"));

    let mut t = Table::new(
        "Parallel scaling (factored kernel, r fixed)",
        &["n", "threads", "matvec/iter serial", "matvec/iter pooled", "mv speedup",
          "divergence serial", "divergence parallel", "div speedup"],
    );

    for &n in &sizes {
        let (mu, nu) = data::gaussian_blobs(n, &mut rng);
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, r, &mut rng);
        let phi_x = map.feature_matrix(&mu.points);
        let phi_y = map.feature_matrix(&nu.points);

        // Serial per-iteration matvec pair (K^T u then K v shapes).
        let v = vec![1.0f32 / n as f32; n];
        let mut mid = vec![0.0f32; r];
        let mut out = vec![0.0f32; n];
        let serial_mv = time(2, reps.max(3) * 3, || {
            matvec_t_into(&phi_y, &v, &mut mid);
            matvec_into(&phi_x, &mid, &mut out);
        })
        .median_s;

        // Serial end-to-end divergence (fixed iteration budget).
        let cfg_serial = SinkhornConfig {
            epsilon: eps,
            max_iters: iters,
            tol: 0.0,
            check_every: iters + 1,
            threads: 1,
        };
        let k_xy = FactoredKernel::from_measures(&map, &mu, &nu);
        let k_xx = FactoredKernel::from_measures(&map, &mu, &mu);
        let k_yy = FactoredKernel::from_measures(&map, &nu, &nu);
        let serial_div = time(1, reps, || {
            sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg_serial)
                .expect("serial divergence");
        })
        .median_s;

        for &threads in &thread_counts {
            let pool = Pool::new(threads);
            let pooled_mv = time(2, reps.max(3) * 3, || {
                matvec_t_into_pooled(&phi_y, &v, &mut mid, &pool);
                matvec_into_pooled(&phi_x, &mid, &mut out, &pool);
            })
            .median_s;

            let cfg_par = SinkhornConfig { threads, ..cfg_serial.clone() };
            let p_xy = FactoredKernel::from_measures_pooled(&map, &mu, &nu, pool);
            let p_xx = FactoredKernel::from_measures_pooled(&map, &mu, &mu, pool);
            let p_yy = FactoredKernel::from_measures_pooled(&map, &nu, &nu, pool);
            let par_div = time(1, reps, || {
                sinkhorn_divergence(&p_xy, &p_xx, &p_yy, &mu.weights, &nu.weights, &cfg_par)
                    .expect("parallel divergence");
            })
            .median_s;

            t.row(vec![
                n.to_string(),
                threads.to_string(),
                fmt_secs(serial_mv),
                fmt_secs(pooled_mv),
                format!("{:.2}x", serial_mv / pooled_mv),
                fmt_secs(serial_div),
                fmt_secs(par_div),
                format!("{:.2}x", serial_div / par_div),
            ]);
        }
    }

    t.emit(Some(args.get_str("csv")));
    println!(
        "\nacceptance bar: divergence speedup > 1.5x at n=10000, threads=4 \
         (EXPERIMENTS.md §Parallel scaling)"
    );
}
