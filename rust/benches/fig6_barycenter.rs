//! Figure 6: Wasserstein barycenter of three corner histograms on the
//! positive sphere (50^2 = 2500 grid points) with the cost
//! c(x,y) = -log x^T y — the Remark-1 kernel, exactly rank-3 factored.
//!
//! Reports: IBP iterations/time via the factored kernel vs the dense
//! materialised kernel (same barycenter, different complexity), mass
//! conservation, and the sharpened-peak location (paper panel e).
//!
//! Run: `cargo bench --bench fig6_barycenter`

use linear_sinkhorn::barycenter::{barycenter, BarycenterConfig};
use linear_sinkhorn::bench::{fmt_secs, Table};
use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::features::{FeatureMap, SphereLinearMap};
use linear_sinkhorn::linalg::softmax_inplace;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

fn main() {
    let args = ArgSpec::new("fig6", "Fig.6 positive-sphere barycenter")
        .opt("side", "50", "grid side (50 = paper's 2500 points)")
        .opt("blur", "0.2", "corner blur")
        .opt("csv", "target/fig6.csv", "csv output")
        .parse();
    let side = args.get_usize("side");
    let grid = data::positive_sphere_grid(side);
    let hists = data::corner_histograms(&grid, args.get_f64("blur"));
    let fm = SphereLinearMap::new(3);
    let phi = fm.feature_matrix(&grid);
    let fk = FactoredKernel::from_factors(phi.clone(), phi);
    let cfg = BarycenterConfig::default();

    let mut table = Table::new(
        "Figure 6 — barycenter on the positive sphere (c = -log x^T y)",
        &["kernel", "support", "iters", "time", "mass", "peak(x,y,z)"],
    );

    // Factored (the paper's representation: r = 3 exactly).
    let sw = Stopwatch::start();
    let bc = barycenter(&fk, &hists.to_vec(), &[], &cfg).expect("factored barycenter");
    let t_fact = sw.elapsed_secs();
    let report = |p: &[f32]| {
        let mass: f64 = p.iter().map(|&x| x as f64).sum();
        let mut sharp = p.to_vec();
        softmax_inplace(&mut sharp, 1000.0);
        let (peak, _) = sharp
            .iter()
            .enumerate()
            .fold((0, f32::NEG_INFINITY), |acc, (i, &v)| if v > acc.1 { (i, v) } else { acc });
        (mass, (grid[(peak, 0)], grid[(peak, 1)], grid[(peak, 2)]))
    };
    let (mass, peak) = report(&bc.p);
    table.row(vec![
        "factored r=3".into(),
        format!("{}x{}", side, side),
        bc.iterations.to_string(),
        fmt_secs(t_fact),
        format!("{mass:.6}"),
        format!("({:.2},{:.2},{:.2})", peak.0, peak.1, peak.2),
    ]);

    // Dense (materialised K): same fixed point, O(n^2) applies.
    let dk = DenseKernel::from_matrix(fk.to_dense(), 1.0);
    let sw = Stopwatch::start();
    let bc_d = barycenter(&dk, &hists.to_vec(), &[], &cfg).expect("dense barycenter");
    let t_dense = sw.elapsed_secs();
    let (mass_d, peak_d) = report(&bc_d.p);
    table.row(vec![
        "dense".into(),
        format!("{}x{}", side, side),
        bc_d.iterations.to_string(),
        fmt_secs(t_dense),
        format!("{mass_d:.6}"),
        format!("({:.2},{:.2},{:.2})", peak_d.0, peak_d.1, peak_d.2),
    ]);

    table.emit(Some(args.get_str("csv")));
    println!("factored speedup over dense: {:.1}x (exact same barycenter)", t_dense / t_fact);

    // Sanity: the two agree.
    let diff: f64 = bc.p.iter().zip(&bc_d.p).map(|(&a, &b)| ((a - b) as f64).abs()).sum();
    println!("L1 difference between factored and dense barycenters: {diff:.2e}");
}
