//! Offline stub of the `xla` PJRT bindings.
//!
//! The L2 runtime (`linear_sinkhorn::runtime`) executes AOT-lowered HLO
//! artifacts through the real `xla` crate (PJRT CPU client). That crate is
//! not part of the offline dependency set, so this stub provides the same
//! API surface with every runtime entry point returning a descriptive
//! error instead of executing. Host-side literal plumbing (`Literal`
//! construction, reshape, readback) works for real, so conversion code and
//! its tests run unchanged; only compilation/execution is unavailable.
//!
//! To enable the real runtime, vendor the actual `xla` crate and point the
//! `xla` path dependency in the workspace `Cargo.toml` at it — no source
//! change in `linear-sinkhorn` is required.

use std::fmt;

/// Stub error type mirroring `xla::Error`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla: {}", self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result alias mirroring `xla::Result`.
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable — this build links the bundled `xla` \
         stub crate; vendor the real `xla` crate (see README.md §Runtime) \
         to execute AOT artifacts"
    )))
}

/// Conversion target for [`Literal::to_vec`]. Only `f32` is needed by the
/// artifact pipeline (every tensor in the AOT graphs is f32).
pub trait FromF32 {
    /// Convert one stored element.
    fn from_f32(x: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(x: f32) -> Self {
        x
    }
}

/// Host-side tensor literal (row-major f32 storage, like the real crate's
/// CPU literals as used by this project).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1(v: &[f32]) -> Literal {
        Literal { data: v.to_vec(), dims: vec![v.len() as i64] }
    }

    /// Reinterpret the buffer with new dimensions (element count must
    /// match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let total: i64 = dims.iter().product();
        if total as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements do not fit {:?}",
                self.data.len(),
                dims
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Read the buffer back as a flat vector.
    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    /// Decompose a tuple literal. Stub: tuples only come from execution,
    /// which the stub cannot perform, so this always errors.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }

    /// The literal's dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module handle. Stub: parsing requires the real bindings.
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse an HLO-text file. Stub: always errors.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping a parsed module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer handle. Stub: unreachable without execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Copy the buffer back to a host literal. Stub: always errors.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable. Stub: cannot be constructed (compilation errors
/// first), methods exist for type-checking only.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments. Stub: always errors.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle. Stub: construction reports the runtime as absent,
/// which the callers surface as `Error::Runtime` / a skipped demo.
pub struct PjRtClient;

impl PjRtClient {
    /// Create a CPU client. Stub: always errors.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    /// Platform name of the backing runtime.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation. Stub: always errors.
    pub fn compile(&self, _c: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.dims(), &[4]);
        let m = l.reshape(&[2, 2]).unwrap();
        assert_eq!(m.dims(), &[2, 2]);
        assert_eq!(m.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn runtime_entry_points_error() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let e = PjRtClient::cpu().unwrap_err();
        assert!(format!("{e}").contains("PJRT is unavailable"));
    }
}
