//! Eps-annealing equivalence suite (integration tier).
//!
//! The annealed solve is an *accelerator*, not a different estimator: at
//! the target eps it must land on the same fixed point as the direct
//! solve (within solver tolerance), and the whole ladder must be
//! bitwise deterministic — across thread counts, across a Plan JSON
//! round-trip, and regardless of which host replays the Plan. The SIMD
//! dispatch arms are covered by CI running this suite under
//! `LINEAR_SINKHORN_SIMD=scalar` as well as the default arm.

use linear_sinkhorn::api::OtProblem;
use linear_sinkhorn::api::Plan;
use linear_sinkhorn::data;
use linear_sinkhorn::rng::Rng;

fn clouds(seed: u64) -> (linear_sinkhorn::data::Measure, linear_sinkhorn::data::Measure) {
    let mut rng = Rng::seed_from(seed);
    data::gaussian_blobs(60, &mut rng)
}

// ------------------------------------------------------------- tolerance

/// Annealing only changes *how we get there*: at the target eps the
/// annealed divergence agrees with the direct one to solver tolerance.
#[test]
fn annealed_divergence_agrees_with_direct_at_target_eps() {
    let (mu, nu) = clouds(7);
    let base = || OtProblem::new(&mu, &nu).epsilon(0.1).rank(24).seed(11).max_iters(8000);

    let direct = base()
        .anneal(false)
        .symmetric_self_solves(false)
        .divergence()
        .expect("direct divergence");
    let annealed = base().anneal(true).divergence().expect("annealed divergence");

    assert!(annealed.xy.rung_iterations.len() > 1, "the schedule must actually anneal");
    assert!(direct.xy.rung_iterations.is_empty(), "the direct solve must not anneal");
    let scale = direct.divergence.abs().max(1e-6);
    let rel = (annealed.divergence - direct.divergence).abs() / scale;
    assert!(rel < 5e-2, "annealed vs direct divergence rel diff {rel} too large");
}

/// Symmetric self-solves replace the two-sided xx/yy solves with a
/// one-dual fixed point for the *same* optimum.
#[test]
fn symmetric_self_solves_agree_with_two_sided() {
    let (mu, nu) = clouds(13);
    let base = || OtProblem::new(&mu, &nu).epsilon(0.2).rank(24).seed(17).max_iters(8000);

    let two_sided =
        base().symmetric_self_solves(false).divergence().expect("two-sided divergence");
    let symmetric =
        base().symmetric_self_solves(true).divergence().expect("symmetric divergence");

    // The cross term is untouched by the flag: bitwise identical.
    assert_eq!(
        symmetric.xy.objective.to_bits(),
        two_sided.xy.objective.to_bits(),
        "xy solve must be unaffected by the self-solve strategy"
    );
    let scale = two_sided.divergence.abs().max(1e-6);
    let rel = (symmetric.divergence - two_sided.divergence).abs() / scale;
    assert!(rel < 5e-2, "symmetric vs two-sided divergence rel diff {rel} too large");
}

// ----------------------------------------------------------- determinism

/// Pool widths must stay numerically transparent through the annealed
/// ladder — the same 1-vs-N contract the direct path already holds.
#[test]
fn annealed_divergence_is_bitwise_across_thread_counts() {
    let (mu, nu) = clouds(23);
    let solve = |threads: usize, solver_threads: usize| {
        let plan = OtProblem::new(&mu, &nu)
            .epsilon(0.1)
            .rank(16)
            .seed(5)
            .anneal(true)
            .threads(threads)
            .solver_threads(solver_threads)
            .plan()
            .expect("annealed plan");
        assert!(plan.schedule.is_some());
        OtProblem::new(&mu, &nu)
            .divergence_planned(&plan)
            .expect("annealed divergence")
    };

    let one = solve(1, 1);
    let many = solve(4, 3);

    assert_eq!(one.divergence.to_bits(), many.divergence.to_bits(), "divergence bits");
    assert_eq!(one.xy.objective.to_bits(), many.xy.objective.to_bits(), "xy bits");
    assert_eq!(one.xx.objective.to_bits(), many.xx.objective.to_bits(), "xx bits");
    assert_eq!(one.yy.objective.to_bits(), many.yy.objective.to_bits(), "yy bits");
    assert_eq!(one.xy.u, many.xy.u, "xy row scalings");
    assert_eq!(one.xy.rung_iterations, many.xy.rung_iterations, "xy rung ladder");
    assert_eq!(one.xx.rung_iterations, many.xx.rung_iterations, "xx rung ladder");
    assert_eq!(one.yy.rung_iterations, many.yy.rung_iterations, "yy rung ladder");
}

/// A Plan that went through JSON carries the schedule and the symmetric
/// flag exactly; replaying it reproduces the original bits.
#[test]
fn annealed_plan_json_roundtrip_replays_bitwise() {
    let (mu, nu) = clouds(31);
    let plan = OtProblem::new(&mu, &nu)
        .epsilon(0.15)
        .rank(16)
        .seed(3)
        .anneal(true)
        .anneal_decay(0.4)
        .plan()
        .expect("annealed plan");
    let wired = Plan::from_json(&plan.to_json()).expect("plan json roundtrip");
    assert_eq!(plan.to_json(), wired.to_json(), "schedule must survive serialization");

    let here = OtProblem::new(&mu, &nu).divergence_planned(&plan).expect("original plan");
    let there = OtProblem::new(&mu, &nu).divergence_planned(&wired).expect("replayed plan");
    assert_eq!(here.divergence.to_bits(), there.divergence.to_bits());
    assert_eq!(here.xy.u, there.xy.u);
    assert_eq!(here.xy.rung_iterations, there.xy.rung_iterations);
}

/// Batch and single annealed solves share one code path per rung; the
/// batch must reproduce the single-pair bits for every pair.
#[test]
fn annealed_batch_replays_single_pair_bits() {
    let (mu, nu) = clouds(43);
    let mut rng = Rng::seed_from(97);
    let mut weights = Vec::new();
    for _ in 0..3 {
        let mut a = rng.normal_vec(mu.len());
        let mut b = rng.normal_vec(nu.len());
        for w in a.iter_mut().chain(b.iter_mut()) {
            *w = w.abs() + 0.05;
        }
        let (sa, sb) = (a.iter().sum::<f32>(), b.iter().sum::<f32>());
        a.iter_mut().for_each(|w| *w /= sa);
        b.iter_mut().for_each(|w| *w /= sb);
        weights.push((a, b));
    }
    let refs: Vec<(&[f32], &[f32])> =
        weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();

    let plan = OtProblem::new(&mu, &nu)
        .epsilon(0.1)
        .rank(16)
        .seed(59)
        .weight_pairs(&refs)
        .anneal(true)
        .plan()
        .expect("annealed batch plan");
    assert!(plan.schedule.is_some());

    let batch =
        OtProblem::new(&mu, &nu).weight_pairs(&refs).divergence_all_planned(&plan);
    for (i, (r, (a, b))) in batch.iter().zip(&weights).enumerate() {
        let r = r.as_ref().unwrap_or_else(|e| panic!("batch pair {i} failed: {e}"));
        let single = OtProblem::new(&mu, &nu)
            .weights(a, b)
            .divergence_planned(&plan)
            .unwrap_or_else(|e| panic!("single pair {i} failed: {e}"));
        assert_eq!(r.divergence.to_bits(), single.divergence.to_bits(), "pair {i}");
        assert_eq!(r.xy.rung_iterations, single.xy.rung_iterations, "pair {i} rungs");
    }
}
