//! Shard fault-injection suite: the sharded scatter/gather solve must be
//! **bitwise identical** to the single-host fused solve under every
//! survivable fault, and fail with typed errors (never panics, never
//! wrong answers) under unsurvivable ones.
//!
//! Fault matrix (ISSUE archetype):
//!
//! | fault                     | mechanism                   | expected       |
//! |---------------------------|-----------------------------|----------------|
//! | worker crash mid-solve    | `Fault::KillOnTask`         | retry, bitwise |
//! | heartbeat timeout (hang)  | `Fault::MuteOnTask`         | retry, bitwise |
//! | duplicated gather frame   | `Fault::DuplicateRecv`      | dedup, bitwise |
//! | out-of-order gather       | `Fault::DelayRecv`          | bitwise        |
//! | late result past deadline | `Fault::DelayRecv` + deadline | retry, bitwise |
//! | corrupt result frame      | `Fault::CorruptRecv`        | typed `Wire`   |
//! | all workers dead          | `Fault::KillOnTask` on all  | typed `Service`|
//! | slow solve, live worker   | `Fault::SlowOnTask`         | no false death |
//!
//! Every schedule is deterministic (`shard::testing::FaultPlan`), so a
//! failure replays exactly. The multi-round membership faults (rejoin
//! storms, flapping workers, partitions that heal, hedging races,
//! overload shed, drain) live in `rust/tests/shard_chaos_soak.rs`.

use std::sync::Arc;
use std::time::Duration;

use linear_sinkhorn::api::{Backend, BackendPref, DivergenceReport, OtProblem, Plan};
use linear_sinkhorn::data::{self, Measure};
use linear_sinkhorn::error::{Error, Result};
use linear_sinkhorn::features::GaussianFeatureMap;
use linear_sinkhorn::kernels::FactoredKernel;
use linear_sinkhorn::metrics::Registry;
use linear_sinkhorn::prelude::legacy::sinkhorn_divergence_batch;
use linear_sinkhorn::rng::Rng;
use linear_sinkhorn::runtime::pool::Pool;
use linear_sinkhorn::shard::{Fault, FaultPlan, ShardConfig, ShardCoordinator};
use linear_sinkhorn::shard::worker::spawn_tcp_worker;

// ---------------------------------------------------------------- fixture

/// A small divergence workload: shared support, per-pair weight skews —
/// exactly the shape of a service fuse group.
fn fixture(pairs: usize) -> (Measure, Measure, Vec<(Vec<f32>, Vec<f32>)>, Plan) {
    let mut rng = Rng::seed_from(41);
    let (mu, nu) = data::gaussian_blobs(14, &mut rng);
    let mut weights = Vec::with_capacity(pairs);
    for _ in 0..pairs {
        let mut a = rng.normal_vec(mu.len());
        let mut b = rng.normal_vec(nu.len());
        for w in a.iter_mut().chain(b.iter_mut()) {
            *w = w.abs() + 0.05;
        }
        let (sa, sb) = (a.iter().sum::<f32>(), b.iter().sum::<f32>());
        a.iter_mut().for_each(|w| *w /= sa);
        b.iter_mut().for_each(|w| *w /= sb);
        weights.push((a, b));
    }
    let refs: Vec<(&[f32], &[f32])> =
        weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    let plan = OtProblem::new(&mu, &nu)
        .epsilon(0.5)
        .rank(8)
        .seed(29)
        .weight_pairs(&refs)
        .plan()
        .unwrap();
    (mu, nu, weights, plan)
}

fn as_refs(weights: &[(Vec<f32>, Vec<f32>)]) -> Vec<(&[f32], &[f32])> {
    weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect()
}

fn local_baseline(
    mu: &Measure,
    nu: &Measure,
    refs: &[(&[f32], &[f32])],
    plan: &Plan,
) -> Vec<Result<DivergenceReport>> {
    OtProblem::new(mu, nu).weight_pairs(refs).divergence_all_planned(plan)
}

fn assert_bitwise(shard: &[Result<DivergenceReport>], local: &[Result<DivergenceReport>]) {
    assert_eq!(shard.len(), local.len());
    for (i, (s, l)) in shard.iter().zip(local).enumerate() {
        let s = s.as_ref().unwrap_or_else(|e| panic!("pair {i} failed over shards: {e}"));
        let l = l.as_ref().expect("local baseline must succeed");
        assert_eq!(s.divergence.to_bits(), l.divergence.to_bits(), "pair {i} divergence");
        assert_eq!(s.xy.objective.to_bits(), l.xy.objective.to_bits(), "pair {i} xy");
        assert_eq!(s.xx.objective.to_bits(), l.xx.objective.to_bits(), "pair {i} xx");
        assert_eq!(s.yy.objective.to_bits(), l.yy.objective.to_bits(), "pair {i} yy");
        assert_eq!(s.xy.u, l.xy.u, "pair {i} duals");
        assert_eq!(s.yy.v, l.yy.v, "pair {i} duals");
        assert_eq!(s.xy.iterations, l.xy.iterations, "pair {i} iterations");
    }
}

/// A config with no accidental timeouts: faults fire only where the test
/// scripts them. Hedging and rejoin are pinned off so this suite's
/// counter assertions see exactly the classic retry ladder (the chaos
/// soak exercises the healing rungs).
fn calm_cfg() -> ShardConfig {
    ShardConfig {
        heartbeat_interval: Duration::from_secs(10),
        heartbeat_timeout: Duration::from_secs(60),
        task_deadline: Duration::from_secs(60),
        max_retries: 2,
        retry_backoff: Duration::from_millis(5),
        hedge_fraction: 0.0,
        rejoin_backoff: Duration::from_secs(60),
        ..ShardConfig::default()
    }
}

// ------------------------------------------------------------ happy path

#[test]
fn fault_free_sharded_solve_matches_legacy_batch_bitwise() {
    let (mu, nu, weights, plan) = fixture(6);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    let shard = ShardCoordinator::in_process(3, calm_cfg(), metrics.clone());
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[1, 2, 3, 4, 5, 6]);
    assert_bitwise(&got, &local);
    assert_eq!(metrics.counter("service.shard.retries").get(), 0);

    // And against the pre-API reference path: same map fit, same kernel
    // construction, same config — `sinkhorn_divergence_batch` computes
    // `xy - 0.5 * (xx + yy)` with the identical arithmetic
    // `DivergenceReport::assemble` ships over the wire.
    let Backend::Factored { rank } = plan.backend else {
        panic!("fixture must plan the factored backend")
    };
    let map = GaussianFeatureMap::fit(&mu, &nu, plan.epsilon, rank, &mut Rng::seed_from(plan.seed));
    let pool = Pool::new(plan.solver_threads);
    let mk = |a: &Measure, b: &Measure| {
        if plan.stabilized_factors {
            FactoredKernel::from_measures_stabilized_pooled(&map, a, b, pool.clone())
        } else {
            FactoredKernel::from_measures_pooled(&map, a, b, pool.clone())
        }
    };
    let (k_xy, k_xx, k_yy) = (mk(&mu, &nu), mk(&mu, &mu), mk(&nu, &nu));
    let legacy = sinkhorn_divergence_batch(&k_xy, &k_xx, &k_yy, &refs, &plan.sinkhorn_config());
    for (i, (s, l)) in got.iter().zip(&legacy).enumerate() {
        let (s, l) = (s.as_ref().unwrap(), l.as_ref().unwrap());
        assert_eq!(
            s.divergence.to_bits(),
            l.to_bits(),
            "pair {i}: sharded divergence must equal the legacy batch bit for bit"
        );
    }
}

// ----------------------------------------------------------- fault matrix

#[test]
fn worker_crash_mid_solve_is_survived_bitwise() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0 crashes the moment its first task arrives: the link drops
    // and its chunk must be re-scattered to worker 1.
    let faults = FaultPlan::new(1).inject(0, Fault::KillOnTask { nth: 1 });
    let shard = ShardCoordinator::in_process_with_faults(2, calm_cfg(), metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert_eq!(shard.live_workers(), 1);
    assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 1);
    assert!(metrics.counter("service.shard.retries").get() >= 1, "crash must trigger a retry");
    assert!(metrics.counter("service.shard.rescattered_pairs").get() >= 1);
    // The metric the dashboards watch is rendered.
    assert!(metrics.render().contains("service.shard.retries"));
}

#[test]
fn heartbeat_timeout_detects_hung_worker() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0 goes mute on its first task: it keeps running but answers
    // neither results nor pongs, so only the heartbeat timeout can tell.
    let cfg = ShardConfig {
        heartbeat_interval: Duration::from_millis(20),
        heartbeat_timeout: Duration::from_millis(250),
        task_deadline: Duration::from_secs(60),
        max_retries: 2,
        retry_backoff: Duration::from_millis(5),
        hedge_fraction: 0.0,
        rejoin_backoff: Duration::from_secs(60),
        ..ShardConfig::default()
    };
    let faults = FaultPlan::new(2).inject(0, Fault::MuteOnTask { nth: 1 });
    let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 1);
    assert_eq!(metrics.counter("service.shard.retries").get(), 1);
    assert!(metrics.counter("service.shard.heartbeats").get() >= 1);
}

#[test]
fn duplicated_gather_frames_are_deduped() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // With heartbeats quiesced (calm_cfg) the first inbound frame on each
    // link is the result; both workers deliver theirs twice.
    let faults = FaultPlan::new(3)
        .inject(0, Fault::DuplicateRecv { nth: 0 })
        .inject(1, Fault::DuplicateRecv { nth: 0 });
    let shard = ShardCoordinator::in_process_with_faults(2, calm_cfg(), metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert_eq!(metrics.counter("service.shard.gathered_results").get(), 2);
    assert_eq!(
        metrics.counter("service.shard.duplicate_results").get(),
        2,
        "each duplicated result frame must be observed and discarded"
    );
    assert_eq!(metrics.counter("service.shard.retries").get(), 0);
}

#[test]
fn delayed_gather_reorders_without_retry() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0's result is held back 50 ms, so worker 1's chunk lands
    // first: an out-of-order gather that must still reassemble in pair
    // order, bit for bit, with no retry.
    let faults = FaultPlan::new(4)
        .inject(0, Fault::DelayRecv { nth: 0, delay: Duration::from_millis(50) });
    let shard = ShardCoordinator::in_process_with_faults(2, calm_cfg(), metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert_eq!(metrics.counter("service.shard.retries").get(), 0);
    assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 0);
}

#[test]
fn late_result_past_deadline_forces_retry_and_stays_bitwise() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0's result is held past the task deadline: the coordinator
    // re-scatters its chunk to worker 1; whichever result lands first
    // wins and the loser is deduped — both carry identical bits.
    let cfg = ShardConfig {
        heartbeat_interval: Duration::from_secs(10),
        heartbeat_timeout: Duration::from_secs(60),
        task_deadline: Duration::from_millis(150),
        max_retries: 2,
        retry_backoff: Duration::from_millis(5),
        hedge_fraction: 0.0,
        rejoin_backoff: Duration::from_secs(60),
        ..ShardConfig::default()
    };
    let faults = FaultPlan::new(5)
        .inject(0, Fault::DelayRecv { nth: 0, delay: Duration::from_millis(600) });
    let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert!(metrics.counter("service.shard.retries").get() >= 1, "deadline must fire");
    assert!(metrics.counter("service.shard.rescattered_pairs").get() >= 1);
}

#[test]
fn slow_solve_answers_pings_and_is_not_falsely_declared_dead() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0 sits on its first solve for 600 ms — three times the
    // heartbeat timeout — but its receive loop keeps answering pings the
    // whole time. Liveness must distinguish "slow" from "dead": no false
    // death, no retry, just a late (bitwise-identical) result. Hedging is
    // pinned off so the speculative path cannot mask a false death.
    let cfg = ShardConfig {
        heartbeat_interval: Duration::from_millis(20),
        heartbeat_timeout: Duration::from_millis(200),
        task_deadline: Duration::from_secs(5),
        max_retries: 2,
        retry_backoff: Duration::from_millis(5),
        hedge_fraction: 0.0,
        rejoin_backoff: Duration::from_secs(60),
        ..ShardConfig::default()
    };
    let faults = FaultPlan::new(10)
        .inject(0, Fault::SlowOnTask { nth: 1, delay: Duration::from_millis(600) });
    let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert_eq!(
        metrics.counter("service.shard.worker_deaths").get(),
        0,
        "a ping-answering straggler must not be declared dead"
    );
    assert_eq!(metrics.counter("service.shard.retries").get(), 0);
    assert_eq!(shard.live_workers(), 2);
    assert!(
        metrics.counter("service.shard.heartbeats").get() >= 1,
        "the wait must actually have been bridged by heartbeats"
    );
}

#[test]
fn random_survivable_fault_plans_preserve_bits() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    // Seeded sweeps of drop/delay/duplicate schedules: every survivable
    // plan must leave the answer bitwise intact. `max_retries: 4` gives
    // five sends per task against at most three scheduled faults, so no
    // schedule can exhaust the budget.
    for seed in [11u64, 12, 13, 14] {
        let faults = FaultPlan::random(seed, 2, 3);
        let cfg = ShardConfig {
            heartbeat_interval: Duration::from_millis(50),
            heartbeat_timeout: Duration::from_secs(30),
            task_deadline: Duration::from_millis(300),
            max_retries: 4,
            retry_backoff: Duration::from_millis(5),
            hedge_fraction: 0.0,
            rejoin_backoff: Duration::from_secs(60),
            ..ShardConfig::default()
        };
        let metrics = Arc::new(Registry::default());
        let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics, &faults);
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&got, &local);
    }
}

// ------------------------------------------------------ unsurvivable path

#[test]
fn corrupt_result_frame_fails_typed_without_retry() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0's result frame is garbled in flight. A deterministic
    // decode failure is not retried: worker 0's pairs fail with a typed
    // wire error while worker 1's half stays bitwise correct.
    let faults = FaultPlan::new(6).inject(0, Fault::CorruptRecv { nth: 0 });
    let shard = ShardCoordinator::in_process_with_faults(2, calm_cfg(), metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_eq!(got.len(), 4);
    // Chunks are contiguous: worker 0 held pairs 0..2, worker 1 pairs 2..4.
    for slot in &got[..2] {
        assert!(matches!(slot, Err(Error::Wire(_))), "corrupt chunk must fail typed: {slot:?}");
    }
    assert_bitwise(&got[2..], &local[2..]);
    assert_eq!(metrics.counter("service.shard.corrupt_payloads").get(), 1);
    assert_eq!(metrics.counter("service.shard.retries").get(), 0, "corruption is not retried");
    assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 1);
}

#[test]
fn all_workers_dead_is_typed_never_a_panic() {
    let (mu, nu, weights, plan) = fixture(3);
    let refs = as_refs(&weights);

    let metrics = Arc::new(Registry::default());
    let faults = FaultPlan::new(7)
        .inject(0, Fault::KillOnTask { nth: 1 })
        .inject(1, Fault::KillOnTask { nth: 1 });
    let shard = ShardCoordinator::in_process_with_faults(2, calm_cfg(), metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_eq!(got.len(), 3);
    for slot in &got {
        assert!(matches!(slot, Err(Error::Service(_))), "expected typed error: {slot:?}");
    }
    assert_eq!(shard.live_workers(), 0);
    // The coordinator stays usable: follow-up groups fail fast, typed.
    let again = shard.solve_group(&plan, &mu, &nu, &refs[..1], None, &[]);
    assert!(matches!(&again[0], Err(Error::Service(_))));
}

// -------------------------------------------------------------- nystrom

#[test]
fn nystrom_plan_shards_bitwise_with_no_shipped_artifact() {
    // A Nyström plan ships no feature map at all: the landmark draw
    // (uniform or farthest-point) is a pure function of `plan.seed`, so
    // every worker rebuilds the bit-identical kernel from the plan alone.
    // Same crash schedule as the factored test: the re-scattered chunk
    // re-draws the same landmarks and lands identical bits.
    let (mu, nu, weights, _) = fixture(4);
    let refs = as_refs(&weights);
    for adaptive in [false, true] {
        let plan = OtProblem::new(&mu, &nu)
            .epsilon(5.0)
            .backend(BackendPref::Nystrom { rank: 6, adaptive })
            .seed(29)
            .weight_pairs(&refs)
            .plan()
            .unwrap();
        assert_eq!(plan.backend, Backend::Nystrom { rank: 6, adaptive });
        let local = local_baseline(&mu, &nu, &refs, &plan);

        let metrics = Arc::new(Registry::default());
        let shard = ShardCoordinator::in_process(2, calm_cfg(), metrics.clone());
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&got, &local);
        assert_eq!(metrics.counter("service.shard.retries").get(), 0);

        let metrics = Arc::new(Registry::default());
        let faults = FaultPlan::new(9).inject(0, Fault::KillOnTask { nth: 1 });
        let shard =
            ShardCoordinator::in_process_with_faults(2, calm_cfg(), metrics.clone(), &faults);
        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&got, &local);
        assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 1);
        assert!(metrics.counter("service.shard.retries").get() >= 1, "adaptive={adaptive}");
    }
}

// ------------------------------------------------------------- annealing

/// An annealed plan for the fixture's clouds: the eps schedule and the
/// symmetric self-solve flag ride the Plan, so every worker anneals
/// through bitwise-identical rungs.
fn annealed_plan(mu: &Measure, nu: &Measure, refs: &[(&[f32], &[f32])]) -> Plan {
    let plan = OtProblem::new(mu, nu)
        .epsilon(0.3)
        .rank(8)
        .seed(29)
        .weight_pairs(refs)
        .anneal(true)
        .plan()
        .unwrap();
    assert!(plan.schedule.is_some(), "explicit anneal must ride the plan");
    assert!(plan.symmetric_self_solves, "symmetric self solves follow annealing");
    plan
}

#[test]
fn annealed_plan_shards_bitwise_with_rung_counts() {
    let (mu, nu, weights, _) = fixture(4);
    let refs = as_refs(&weights);
    let plan = annealed_plan(&mu, &nu, &refs);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    let shard = ShardCoordinator::in_process(2, calm_cfg(), metrics.clone());
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert_eq!(metrics.counter("service.shard.retries").get(), 0);
    // The per-rung iteration counts survive the wire exactly.
    for (i, (s, l)) in got.iter().zip(&local).enumerate() {
        let (s, l) = (s.as_ref().unwrap(), l.as_ref().unwrap());
        assert!(s.xy.rung_iterations.len() > 1, "pair {i} must have annealed");
        assert_eq!(s.xy.rung_iterations, l.xy.rung_iterations, "pair {i} xy rungs");
        assert_eq!(s.xx.rung_iterations, l.xx.rung_iterations, "pair {i} xx rungs");
        assert_eq!(s.yy.rung_iterations, l.yy.rung_iterations, "pair {i} yy rungs");
    }
}

#[test]
fn annealed_plan_survives_worker_crash_bitwise() {
    let (mu, nu, weights, _) = fixture(4);
    let refs = as_refs(&weights);
    let plan = annealed_plan(&mu, &nu, &refs);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Same crash schedule as the direct-plan test: the re-scattered chunk
    // re-anneals from the schedule in the plan and lands identical bits.
    let faults = FaultPlan::new(8).inject(0, Fault::KillOnTask { nth: 1 });
    let shard = ShardCoordinator::in_process_with_faults(2, calm_cfg(), metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 1);
    assert!(metrics.counter("service.shard.retries").get() >= 1);
}

// ------------------------------------------------------------ cross-host

#[test]
fn tcp_loopback_workers_match_local_bitwise() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let (addr_a, join_a) = spawn_tcp_worker(0).unwrap();
    let (addr_b, join_b) = spawn_tcp_worker(1).unwrap();
    let metrics = Arc::new(Registry::default());
    let shard = ShardCoordinator::connect(
        &[addr_a.to_string(), addr_b.to_string()],
        calm_cfg(),
        metrics.clone(),
    )
    .unwrap();
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[7, 8, 9, 10]);
    assert_bitwise(&got, &local);
    assert_eq!(metrics.counter("service.shard.gathered_results").get(), 2);
    drop(shard); // shutdown frames / closed links let the workers exit
    join_a.join().unwrap();
    join_b.join().unwrap();
}
