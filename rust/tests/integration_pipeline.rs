//! Cross-module integration tests: data -> features -> kernels -> sinkhorn
//! -> divergence, plus property tests over the whole pipeline using the
//! in-repo mini property harness.

use linear_sinkhorn::config::SinkhornConfig;
use linear_sinkhorn::features::FeatureMap;
use linear_sinkhorn::prelude::*;
// These pipeline properties exercise the reference free-function layer
// (prelude::legacy); rust/tests/api_equivalence.rs proves the planned API
// matches it bitwise.
use linear_sinkhorn::sinkhorn::{marginal_errors, sinkhorn, sinkhorn_divergence, transport_plan};
use linear_sinkhorn::testing::property;

fn cfg(eps: f64) -> SinkhornConfig {
    SinkhornConfig {
        epsilon: eps,
        max_iters: 3000,
        tol: 1e-5,
        check_every: 5,
        threads: 1,
        stabilize: false,
        max_batch: 1,
        anneal: None,
        anneal_decay: 0.5,
        symmetric: None,
    }
}

#[test]
fn full_pipeline_gaussian_to_divergence() {
    let mut rng = Rng::seed_from(0);
    let (mu, nu) = data::gaussian_blobs(300, &mut rng);
    let eps = 0.5;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 400, &mut rng);
    let k_xy = FactoredKernel::from_measures(&map, &mu, &nu);
    let k_xx = FactoredKernel::from_measures(&map, &mu, &mu);
    let k_yy = FactoredKernel::from_measures(&map, &nu, &nu);
    let d = sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg(eps))
        .expect("pipeline");
    assert!(d > 0.0 && d.is_finite(), "divergence {d}");
}

#[test]
fn property_sinkhorn_feasibility_over_random_problems() {
    // For random positive factor matrices, Alg. 1 always converges to a
    // feasible plan (positivity by construction =>, no divergence).
    property("sinkhorn_feasibility", 20, |g| {
        let n = g.usize_in(3, 40);
        let m = g.usize_in(3, 40);
        let r = g.usize_in(1, 12);
        let phi_x = g.positive_mat(n, r, 0.05, 2.0);
        let phi_y = g.positive_mat(m, r, 0.05, 2.0);
        let a = g.simplex(n);
        let b = g.simplex(m);
        let k = FactoredKernel::from_factors(phi_x, phi_y);
        let sol = sinkhorn(&k, &a, &b, &cfg(0.5)).expect("positive factors never diverge");
        let (row_err, col_err) = marginal_errors(&k, &sol, &a, &b);
        assert!(row_err < 1e-3, "row err {row_err}");
        assert!(col_err < 1e-3, "col err {col_err}");
    });
}

#[test]
fn property_plan_is_nonnegative_and_mass_one() {
    property("plan_mass", 10, |g| {
        let n = g.usize_in(3, 15);
        let r = g.usize_in(1, 6);
        let phi_x = g.positive_mat(n, r, 0.1, 1.5);
        let phi_y = g.positive_mat(n, r, 0.1, 1.5);
        let a = g.simplex(n);
        let b = g.simplex(n);
        let k = FactoredKernel::from_factors(phi_x, phi_y);
        let sol = sinkhorn(&k, &a, &b, &cfg(1.0)).unwrap();
        let plan = transport_plan(&k, &sol);
        assert!(plan.min_entry() >= 0.0);
        let mass: f64 = plan.data().iter().map(|&x| x as f64).sum();
        assert!((mass - 1.0).abs() < 1e-3, "mass {mass}");
    });
}

#[test]
fn property_divergence_is_symmetric() {
    // Wbar(mu, nu) == Wbar(nu, mu) when the same features are used.
    property("divergence_symmetry", 6, |g| {
        let n = g.usize_in(10, 40);
        let mut rng = Rng::seed_from(g.rng.next_u64());
        let mu = Measure::uniform(g.cloud(n, 2, 1.0));
        let nu = Measure::uniform(g.cloud(n, 2, 0.7));
        let eps = 0.5;
        let map = GaussianFeatureMap::fit(&mu, &nu, eps, 256, &mut rng);
        let kxy = FactoredKernel::from_measures(&map, &mu, &nu);
        let kyx = FactoredKernel::from_measures(&map, &nu, &mu);
        let kxx = FactoredKernel::from_measures(&map, &mu, &mu);
        let kyy = FactoredKernel::from_measures(&map, &nu, &nu);
        let d1 = sinkhorn_divergence(&kxy, &kxx, &kyy, &mu.weights, &nu.weights, &cfg(eps))
            .unwrap();
        let d2 = sinkhorn_divergence(&kyx, &kyy, &kxx, &nu.weights, &mu.weights, &cfg(eps))
            .unwrap();
        assert!((d1 - d2).abs() < 1e-5 * d1.abs().max(1.0), "{d1} vs {d2}");
    });
}

#[test]
fn property_kernel_ratio_tightens_with_more_features() {
    // Prop 3.1 shape: sup ratio error shrinks as r grows (on average).
    property("ratio_vs_r", 4, |g| {
        let mut rng = Rng::seed_from(g.rng.next_u64());
        let mu = Measure::uniform(g.cloud(12, 2, 0.8));
        let nu = Measure::uniform(g.cloud(12, 2, 0.8));
        let eps = 1.0;
        let err_at = |r: usize, rng: &mut Rng| -> f64 {
            // Average over a few draws to damp MC noise.
            let mut tot = 0.0;
            for _ in 0..3 {
                let map = GaussianFeatureMap::fit(&mu, &nu, eps, r, rng);
                let fk = FactoredKernel::from_measures(&map, &mu, &nu);
                let kd = fk.to_dense();
                let mut worst = 0.0f64;
                for i in 0..mu.len() {
                    for j in 0..nu.len() {
                        let d2: f64 = mu
                            .points
                            .row(i)
                            .iter()
                            .zip(nu.points.row(j))
                            .map(|(&a, &b)| ((a - b) as f64).powi(2))
                            .sum();
                        let truth = (-d2 / eps).exp();
                        worst = worst.max(((kd[(i, j)] as f64) / truth - 1.0).abs());
                    }
                }
                tot += worst;
            }
            tot / 3.0
        };
        let few = err_at(32, &mut rng);
        let many = err_at(1024, &mut rng);
        assert!(many < few, "ratio error should shrink: r=32 -> {few:.3}, r=1024 -> {many:.3}");
    });
}

#[test]
fn rf_tracks_log_domain_ground_truth() {
    // End-to-end accuracy vs the stabilised dense solver.
    let mut rng = Rng::seed_from(5);
    let (mu, nu) = data::gaussian_blobs(120, &mut rng);
    let eps = 1.0;
    let truth = linear_sinkhorn::bench::tradeoff::ground_truth(&mu, &nu, eps);
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 1200, &mut rng);
    let fk = FactoredKernel::from_measures(&map, &mu, &nu);
    let est = sinkhorn(&fk, &mu.weights, &nu.weights, &cfg(eps)).unwrap().objective;
    let dev = linear_sinkhorn::sinkhorn::deviation_score(truth, est);
    assert!((dev - 100.0).abs() < 6.0, "deviation {dev} (truth {truth} est {est})");
}

#[test]
fn arccos_features_run_through_sinkhorn() {
    use linear_sinkhorn::features::ArcCosFeatureMap;
    let mut rng = Rng::seed_from(6);
    let (mu, nu) = data::gaussian_blobs(80, &mut rng);
    let fm = ArcCosFeatureMap::new(2, 128, 1, 0.2, 1.5, &mut rng);
    let phi_x = fm.feature_matrix(&mu.points);
    let phi_y = fm.feature_matrix(&nu.points);
    let k = FactoredKernel::from_factors(phi_x, phi_y);
    let sol = sinkhorn(&k, &mu.weights, &nu.weights, &cfg(0.5)).expect("arc-cosine kernel");
    assert!(sol.objective.is_finite());
    assert!(sol.marginal_error < 1e-3);
}

#[test]
fn property_config_cli_roundtrip() {
    use linear_sinkhorn::config::ConfigDoc;
    property("config_roundtrip", 25, |g| {
        let eps = g.f64_in(0.01, 10.0);
        let iters = g.usize_in(1, 100000);
        let text = format!("[sinkhorn]\nepsilon = {eps}\nmax_iters = {iters}");
        let doc = ConfigDoc::parse(&text).unwrap();
        let cfg = SinkhornConfig::from_doc(&doc);
        assert!((cfg.epsilon - eps).abs() < 1e-12);
        assert_eq!(cfg.max_iters, iters);
    });
}
