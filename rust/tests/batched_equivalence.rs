//! Batched-vs-sequential equivalence: the multi-pair solve engine
//! (`solve_batch` + the fused column-blocked kernels behind
//! `apply_batch_*`) must change wall-clock only, never numbers.
//!
//! The guarantees asserted here, at sizes that cross every fixed chunk
//! grid (transpose chunks of 1024 rows, logsumexp grids, row chunks):
//! 1. `solve_batch` over B weight pairs is **bitwise** equal — scalings,
//!    objective, iteration count, convergence flag — to B sequential
//!    `sinkhorn` calls on the same kernel, for B ∈ {1, 3, 7}, with mixed
//!    per-pair convergence speeds (masking freezes early finishers), and
//!    with 1-vs-N-thread kernel pools.
//! 2. `solve_batch_log_domain` obeys the same contract against
//!    `sinkhorn_log_domain`.
//! 3. A diverging pair errors exactly like its sequential solve and never
//!    perturbs its batch-mates.
//! 4. `sinkhorn_divergence_batch` reproduces per-pair
//!    `sinkhorn_divergence` bit for bit at any solve-level thread count.

use linear_sinkhorn::config::SinkhornConfig;
use linear_sinkhorn::prelude::*;
// The reference free-function layer is the baseline these properties
// compare the batched engine against (re-exported as prelude::legacy).
use linear_sinkhorn::sinkhorn::{
    sinkhorn, sinkhorn_divergence, sinkhorn_divergence_batch, sinkhorn_log_domain, solve_batch,
    solve_batch_log_domain,
};

fn cfg(eps: f64) -> SinkhornConfig {
    SinkhornConfig {
        epsilon: eps,
        max_iters: 80,
        tol: 1e-4,
        check_every: 1,
        threads: 1,
        stabilize: false,
        max_batch: 8,
        anneal: None,
        anneal_decay: 0.5,
        symmetric: None,
    }
}

/// B positive weight vectors of length n with salt-dependent skews, each
/// summing to one: different skews converge at different iterations,
/// which is what exercises per-pair masking.
fn weight_family(n: usize, b: usize, salt: usize) -> Vec<Vec<f32>> {
    (0..b)
        .map(|k| {
            let raw: Vec<f64> = (0..n)
                .map(|i| 1.0 + ((i * (k + salt + 2) + k) % 9) as f64 * (0.15 + k as f64 * 0.35))
                .collect();
            let total: f64 = raw.iter().sum();
            raw.iter().map(|&x| (x / total) as f32).collect()
        })
        .collect()
}

fn as_pairs<'a>(ws_a: &'a [Vec<f32>], ws_b: &'a [Vec<f32>]) -> Vec<(&'a [f32], &'a [f32])> {
    ws_a.iter().zip(ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect()
}

#[test]
fn solve_batch_bitwise_equals_sequential_across_widths_and_threads() {
    // n = 1500 crosses the 1024-row transpose chunk grid, so the fused
    // mat-mat applies run the chunked reduction for real.
    let mut rng = Rng::seed_from(0);
    let (mu, nu) = data::gaussian_blobs(1500, &mut rng);
    let eps = 0.5;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 48, &mut rng);
    // Generous iteration budget with per-iteration checks: the skewed
    // weight families converge at visibly different counts, so masking
    // (freezing finished columns mid-batch) really runs.
    let c = SinkhornConfig { max_iters: 400, ..cfg(eps) };

    // Sequential reference, serial kernel: one solve per pair.
    let serial_kernel = FactoredKernel::from_measures(&map, &mu, &nu);
    let ws_a = weight_family(mu.len(), 7, 0);
    let ws_b = weight_family(nu.len(), 7, 3);
    let pairs = as_pairs(&ws_a, &ws_b);
    let reference: Vec<SinkhornSolution> =
        pairs.iter().map(|&(a, b)| sinkhorn(&serial_kernel, a, b, &c).unwrap()).collect();
    let iters: Vec<usize> = reference.iter().map(|s| s.iterations).collect();
    let mut distinct = iters.clone();
    distinct.sort_unstable();
    distinct.dedup();
    assert!(
        distinct.len() > 1,
        "weight family too uniform to exercise masking: {iters:?}"
    );

    for threads in [1usize, 4] {
        let pool = Pool::new(threads);
        let kernel = FactoredKernel::from_measures_pooled(&map, &mu, &nu, pool);
        for &b in &[1usize, 3, 7] {
            let batched = solve_batch(&kernel, &pairs[..b], &c);
            for (p, got) in batched.iter().enumerate() {
                let got = got.as_ref().unwrap();
                let want = &reference[p];
                assert_eq!(
                    got.objective.to_bits(),
                    want.objective.to_bits(),
                    "objective, B={b} threads={threads} pair {p}"
                );
                assert_eq!(got.iterations, want.iterations, "B={b} threads={threads} pair {p}");
                assert_eq!(got.converged, want.converged, "B={b} threads={threads} pair {p}");
                assert_eq!(
                    got.marginal_error.to_bits(),
                    want.marginal_error.to_bits(),
                    "marginal, B={b} threads={threads} pair {p}"
                );
                for (i, (gu, wu)) in got.u.iter().zip(&want.u).enumerate() {
                    assert_eq!(
                        gu.to_bits(),
                        wu.to_bits(),
                        "u[{i}], B={b} threads={threads} pair {p}"
                    );
                }
                for (j, (gv, wv)) in got.v.iter().zip(&want.v).enumerate() {
                    assert_eq!(
                        gv.to_bits(),
                        wv.to_bits(),
                        "v[{j}], B={b} threads={threads} pair {p}"
                    );
                }
            }
        }
    }
}

#[test]
fn solve_batch_log_domain_bitwise_equals_sequential() {
    // n = 1200 crosses the 1024-row logsumexp chunk grid; eps = 1e-3 is
    // the regime the log path exists for.
    let mut rng = Rng::seed_from(1);
    let (mu, nu) = data::gaussian_blobs(1200, &mut rng);
    let eps = 1e-3;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 32, &mut rng);
    let lx = map.log_feature_matrix(&mu.points);
    let ly = map.log_feature_matrix(&nu.points);
    let c = SinkhornConfig { max_iters: 25, check_every: 5, ..cfg(eps) };

    let serial_kernel = FactoredKernel::from_log_factors(lx.clone(), ly.clone());
    let ws_a = weight_family(mu.len(), 3, 1);
    let ws_b = weight_family(nu.len(), 3, 4);
    let pairs = as_pairs(&ws_a, &ws_b);
    let reference: Vec<SinkhornSolution> = pairs
        .iter()
        .map(|&(a, b)| sinkhorn_log_domain(&serial_kernel, a, b, &c).unwrap())
        .collect();

    for threads in [1usize, 4] {
        let pool = Pool::new(threads);
        let kernel =
            FactoredKernel::from_log_factors(lx.clone(), ly.clone()).with_pool(pool);
        let batched = solve_batch_log_domain(&kernel, &pairs, &c);
        for (p, got) in batched.iter().enumerate() {
            let got = got.as_ref().unwrap();
            let want = &reference[p];
            assert_eq!(
                got.objective.to_bits(),
                want.objective.to_bits(),
                "objective, threads={threads} pair {p}"
            );
            assert_eq!(got.iterations, want.iterations, "threads={threads} pair {p}");
            assert_eq!(
                got.marginal_error.to_bits(),
                want.marginal_error.to_bits(),
                "marginal, threads={threads} pair {p}"
            );
            for (i, (gu, wu)) in got.u.iter().zip(&want.u).enumerate() {
                assert_eq!(gu.to_bits(), wu.to_bits(), "u[{i}], threads={threads} pair {p}");
            }
        }
    }
}

#[test]
fn diverging_pair_errors_alone_and_exactly_like_sequential() {
    let mut rng = Rng::seed_from(2);
    let (mu, nu) = data::gaussian_blobs(40, &mut rng);
    let eps = 0.5;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 32, &mut rng);
    let kernel = FactoredKernel::from_measures(&map, &mu, &nu);
    let c = cfg(eps);
    // An all-zero b drives v to zero at the first update — the sequential
    // solver reports SinkhornDiverged at its first check.
    let zero_b = vec![0.0f32; nu.len()];
    let pairs: Vec<(&[f32], &[f32])> = vec![
        (&mu.weights, &nu.weights),
        (&mu.weights, &zero_b),
        (&mu.weights, &nu.weights),
    ];
    let batched = solve_batch(&kernel, &pairs, &c);

    let want_ok = sinkhorn(&kernel, &mu.weights, &nu.weights, &c).unwrap();
    for p in [0usize, 2] {
        let got = batched[p].as_ref().unwrap();
        assert_eq!(
            got.objective.to_bits(),
            want_ok.objective.to_bits(),
            "healthy pair {p} perturbed by a diverging batch-mate"
        );
    }
    let want_err = sinkhorn(&kernel, &mu.weights, &zero_b, &c);
    match (&batched[1], want_err) {
        (
            Err(Error::SinkhornDiverged { iter: bi, reason: br }),
            Err(Error::SinkhornDiverged { iter: si, reason: sr }),
        ) => {
            assert_eq!(*bi, si, "divergence iteration must match the sequential solve");
            assert_eq!(*br, sr, "divergence reason must match the sequential solve");
        }
        other => panic!("expected matching SinkhornDiverged, got {other:?}"),
    }
}

#[test]
fn divergence_batch_bitwise_equals_sequential_at_any_thread_count() {
    let mut rng = Rng::seed_from(3);
    let (mu, nu) = data::gaussian_blobs(200, &mut rng);
    let eps = 0.5;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 64, &mut rng);
    let k_xy = FactoredKernel::from_measures(&map, &mu, &nu);
    let k_xx = FactoredKernel::from_measures(&map, &mu, &mu);
    let k_yy = FactoredKernel::from_measures(&map, &nu, &nu);
    let ws_a = weight_family(mu.len(), 3, 2);
    let ws_b = weight_family(nu.len(), 3, 5);
    let pairs = as_pairs(&ws_a, &ws_b);
    let c1 = cfg(eps);

    let reference: Vec<f64> = pairs
        .iter()
        .map(|&(a, b)| sinkhorn_divergence(&k_xy, &k_xx, &k_yy, a, b, &c1).unwrap())
        .collect();
    for threads in [1usize, 3] {
        let c = SinkhornConfig { threads, ..c1.clone() };
        let batched = sinkhorn_divergence_batch(&k_xy, &k_xx, &k_yy, &pairs, &c);
        for (p, got) in batched.iter().enumerate() {
            let got = got.as_ref().unwrap();
            assert_eq!(
                got.to_bits(),
                reference[p].to_bits(),
                "pair {p} threads={threads}: {got} vs {}",
                reference[p]
            );
        }
    }
}

/// SIMD-core extension of guarantee 1: the fused column-blocked kernels
/// stay bitwise identical per pair to the vector kernels **on each
/// dispatch arm**, at sizes that straddle the 8/16-lane f32 and 4-lane
/// f64 boundaries, the 8-row saxpy microkernel, and the fixed chunk
/// grids — including empty and single-row inputs. (On machines without
/// AVX2+FMA the second arm sanitises to scalar and the pairs coincide.)
#[test]
fn fused_kernels_bitwise_per_pair_on_both_dispatch_arms() {
    use linear_sinkhorn::linalg::simd::SimdLevel;
    use linear_sinkhorn::linalg::{
        lse_matmat_into_at, lse_matmat_t_into_at, lse_matvec_into_at, lse_matvec_t_into_at,
        matmat_into_at, matmat_t_into_at, matvec_into_at, matvec_t_into_at, Mat,
    };

    let mut rng = Rng::seed_from(41);
    for level in [SimdLevel::Scalar, SimdLevel::Avx2Fma.sanitize()] {
        for &(n, k, b) in &[
            (0usize, 5usize, 2usize),
            (1, 1, 1),
            (7, 9, 3),
            (16, 8, 2),
            (17, 12, 4),
            (1025, 33, 3),
        ] {
            let a = Mat::from_fn(n, k, |_, _| rng.normal_f32());
            let vs = Mat::from_fn(b, k, |_, _| rng.normal_f32());
            let us = Mat::from_fn(b, n, |_, _| rng.normal_f32());
            let mut fused = Mat::zeros(b, n);
            matmat_into_at(level, &a, &vs, &mut fused);
            let mut fused_t = Mat::zeros(b, k);
            matmat_t_into_at(level, &a, &us, &mut fused_t);

            let ts: Vec<Vec<f64>> = (0..b)
                .map(|p| (0..k).map(|j| (p * 5 + j) as f64 * 0.7 - 20.0).collect())
                .collect();
            let ws: Vec<Vec<f64>> = (0..b)
                .map(|p| (0..n).map(|i| (p * 3 + i) as f64 * 0.4 - 15.0).collect())
                .collect();
            let mut louts: Vec<Vec<f64>> = (0..b).map(|_| vec![0.0f64; n]).collect();
            lse_matmat_into_at(level, &a, -1.1, &ts, &mut louts);
            let mut louts_t: Vec<Vec<f64>> = (0..b).map(|_| vec![0.0f64; k]).collect();
            lse_matmat_t_into_at(level, &a, -1.1, &ws, &mut louts_t);

            for p in 0..b {
                let mut want = vec![0.0f32; n];
                matvec_into_at(level, &a, vs.row(p), &mut want);
                assert!(
                    fused.row(p).iter().zip(&want).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} matmat ({n},{k},{b}) pair {p}",
                    level.label()
                );
                let mut want_t = vec![0.0f32; k];
                matvec_t_into_at(level, &a, us.row(p), &mut want_t);
                assert!(
                    fused_t.row(p).iter().zip(&want_t).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} matmat_t ({n},{k},{b}) pair {p}",
                    level.label()
                );
                let mut lwant = vec![0.0f64; n];
                lse_matvec_into_at(level, &a, -1.1, &ts[p], &mut lwant);
                assert!(
                    louts[p].iter().zip(&lwant).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} lse_matmat ({n},{k},{b}) pair {p}",
                    level.label()
                );
                let mut lwant_t = vec![0.0f64; k];
                lse_matvec_t_into_at(level, &a, -1.1, &ws[p], &mut lwant_t);
                assert!(
                    louts_t[p].iter().zip(&lwant_t).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} lse_matmat_t ({n},{k},{b}) pair {p}",
                    level.label()
                );
            }
        }
    }
}
