//! Streaming-session equivalence properties — the acceptance suite of
//! the session subsystem:
//!
//! * **Incremental = from-scratch**: a session mutated through an op log
//!   answers (within solver tolerance) what a fresh session opened on
//!   the final snapshot with the *same map* answers. The supports are
//!   bit-identical rows in a possibly different order (swap-remove
//!   layout), so the objectives agree to tolerance, not bits.
//! * **Zero-delta is invisible**: an empty `update()` between two
//!   queries changes nothing — the identity remap fast path hands the
//!   next solve the bit-exact dual, so objective and iteration count
//!   match a session that never saw the empty update.
//! * **Thread-count transparency**: the same op log replayed at
//!   `solver_threads` 1 and 4 yields bitwise-identical queries.
//! * **Full eviction degrades gracefully**: evicting every x row leaves
//!   a session that errors typed on query and recovers (cold) once
//!   points are inserted again.
//! * **Eps change = cold restart**: after `set_epsilon` the session is
//!   bit-identical to a fresh session opened at the new eps with the
//!   same seed over the current snapshot.
//! * **Sharded = local**: the service's session API answers with the
//!   same bits whether queries solve in-process or on a shard worker's
//!   resident copy (delta replay + warm dual over the wire).
//!
//! SIMD arms: the suite runs under whatever arm the process dispatches;
//! CI runs it twice (default + `LINEAR_SINKHORN_SIMD=scalar`), which is
//! what "both arms" means everywhere in this repo.

use std::sync::Arc;

use linear_sinkhorn::config::BatcherConfig;
use linear_sinkhorn::coordinator::Service;
use linear_sinkhorn::prelude::*;

fn clouds(seed: u64, n: usize) -> (Measure, Measure) {
    let mut rng = Rng::seed_from(seed);
    data::gaussian_blobs(n, &mut rng)
}

fn session_cfg(eps: f64, threads: usize) -> SessionConfig {
    SessionConfig {
        sinkhorn: SinkhornConfig { epsilon: eps, ..SinkhornConfig::default() },
        rank: 32,
        seed: 23,
        solver_threads: threads,
    }
}

fn point(rng: &mut Rng, dim: usize) -> Vec<f32> {
    (0..dim).map(|_| rng.uniform_in(-0.5, 0.5) as f32).collect()
}

/// A mixed op log touching both sides: inserts, swap-remove evictions,
/// and in-place swaps, all deterministic from `seed`.
fn op_log(seed: u64, dim: usize, rounds: usize) -> Vec<SessionOp> {
    let mut rng = Rng::seed_from(seed);
    let mut ops = Vec::new();
    for i in 0..rounds {
        ops.push(SessionOp::InsertX { point: point(&mut rng, dim), weight: 1.0 });
        ops.push(SessionOp::SwapY { index: i, point: point(&mut rng, dim), weight: 0.5 });
        ops.push(SessionOp::EvictX { index: i });
        ops.push(SessionOp::InsertY { point: point(&mut rng, dim), weight: 0.25 });
    }
    ops
}

#[test]
fn incremental_session_matches_from_scratch_within_tolerance() {
    let (mu, nu) = clouds(1, 80);
    let mut s = StreamingSession::new(&mu, &nu, session_cfg(0.2, 1)).unwrap();
    s.update(&op_log(5, mu.dim(), 12)).unwrap();
    let incremental = s.query().unwrap();

    // From scratch on the final snapshot, sharing the session's exact
    // map (the supports are the same points in the session's layout, so
    // this isolates the incremental row maintenance).
    let (mu2, nu2) = s.state().snapshot();
    let map = s.state().map().clone();
    let mut fresh =
        StreamingSession::with_map(&mu2, &nu2, map, session_cfg(0.2, 1)).unwrap();
    let scratch = fresh.query().unwrap();

    // Identical layout + identical rows => identical marginals and
    // kernel: the cold solves are actually bitwise here, but the
    // contract we promise is tolerance-level agreement.
    let rel = (incremental.objective - scratch.objective).abs()
        / scratch.objective.abs().max(1e-12);
    assert!(
        rel < 1e-6,
        "incremental {} vs scratch {} (rel {rel:.3e})",
        incremental.objective,
        scratch.objective
    );
}

#[test]
fn zero_delta_update_is_bitwise_invisible() {
    let build = || {
        let (mu, nu) = clouds(2, 60);
        StreamingSession::new(&mu, &nu, session_cfg(0.3, 1)).unwrap()
    };
    let mut plain = build();
    let mut nudged = build();
    let p1 = plain.query().unwrap();
    let n1 = nudged.query().unwrap();
    assert_eq!(p1.objective.to_bits(), n1.objective.to_bits());

    // The empty update bumps the version but must not perturb the warm
    // start: the identity remap copies the dual verbatim.
    nudged.update(&[]).unwrap();
    let p2 = plain.query().unwrap();
    let n2 = nudged.query().unwrap();
    assert!(p2.warm_started && n2.warm_started);
    assert_eq!(p2.objective.to_bits(), n2.objective.to_bits());
    assert_eq!(p2.iterations, n2.iterations);
    assert_eq!(p2.marginal_error.to_bits(), n2.marginal_error.to_bits());
    assert_eq!(n2.version, 1);
}

#[test]
fn update_log_replay_is_bitwise_across_thread_counts() {
    let (mu, nu) = clouds(3, 90);
    let run = |threads: usize| {
        let mut s =
            StreamingSession::new(&mu, &nu, session_cfg(0.2, threads)).unwrap();
        let mut out = Vec::new();
        let q = s.query().unwrap();
        out.push((q.objective, q.iterations));
        for chunk in op_log(9, mu.dim(), 10).chunks(4) {
            s.update(chunk).unwrap();
            let q = s.query().unwrap();
            out.push((q.objective, q.iterations));
        }
        out
    };
    let one = run(1);
    let four = run(4);
    for (a, b) in one.iter().zip(&four) {
        assert_eq!(a.0.to_bits(), b.0.to_bits(), "{a:?} vs {b:?}");
        assert_eq!(a.1, b.1, "{a:?} vs {b:?}");
    }
}

#[test]
fn evicting_every_row_degrades_gracefully_and_recovers_cold() {
    let (mu, nu) = clouds(4, 16);
    let n = mu.len();
    let dim = mu.dim();
    let mut s = StreamingSession::new(&mu, &nu, session_cfg(0.4, 1)).unwrap();
    let _ = s.query().unwrap();
    // High -> low evicts the tail row each time: no swap-remove moves,
    // and after n ops the x side is empty.
    let evictions: Vec<SessionOp> =
        (0..n).rev().map(|i| SessionOp::EvictX { index: i }).collect();
    s.update(&evictions).unwrap();
    assert!(matches!(s.query(), Err(Error::Shape(_))), "empty side must error typed");

    // Recovery: new points, cold solve (the old dual has no survivors).
    let mut rng = Rng::seed_from(44);
    let inserts: Vec<SessionOp> = (0..8)
        .map(|_| SessionOp::InsertX { point: point(&mut rng, dim), weight: 1.0 })
        .collect();
    s.update(&inserts).unwrap();
    let q = s.query().unwrap();
    assert!(!q.warm_started, "nothing survived eviction; the solve must be cold");
    assert!(q.objective.is_finite());
}

#[test]
fn eps_change_matches_fresh_session_at_new_eps_bitwise() {
    let (mu, nu) = clouds(5, 70);
    let mut s = StreamingSession::new(&mu, &nu, session_cfg(0.5, 1)).unwrap();
    let _ = s.query().unwrap();
    s.update(&op_log(13, mu.dim(), 6)).unwrap();
    s.set_epsilon(0.125).unwrap();
    let restarted = s.query().unwrap();
    assert!(!restarted.warm_started, "eps change must drop the dual");

    // A fresh session at the new eps over the current snapshot, same
    // seed: set_epsilon refits from the session seed, so the two maps —
    // and everything downstream — are bit-identical.
    let (mu2, nu2) = s.state().snapshot();
    let mut fresh =
        StreamingSession::new(&mu2, &nu2, session_cfg(0.125, 1)).unwrap();
    let cold = fresh.query().unwrap();
    assert_eq!(restarted.objective.to_bits(), cold.objective.to_bits());
    assert_eq!(restarted.iterations, cold.iterations);
    assert_eq!(restarted.marginal_error.to_bits(), cold.marginal_error.to_bits());
}

#[test]
fn shared_map_arc_sessions_agree_bitwise() {
    // Two sessions sharing one map Arc (the coordinator's cache-sharing
    // pattern) answer identically to a session owning its own fit.
    let (mu, nu) = clouds(6, 50);
    let cfg = session_cfg(0.25, 1);
    let mut rng = Rng::seed_from(cfg.seed);
    let map = Arc::new(GaussianFeatureMap::fit(
        &mu,
        &nu,
        cfg.sinkhorn.epsilon,
        cfg.rank,
        &mut rng,
    ));
    let mut owned = StreamingSession::new(&mu, &nu, cfg.clone()).unwrap();
    let mut shared = StreamingSession::with_map(&mu, &nu, map, cfg).unwrap();
    let a = owned.query().unwrap();
    let b = shared.query().unwrap();
    assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    assert_eq!(a.iterations, b.iterations);
}

#[test]
fn sharded_session_serving_is_bitwise_local() {
    // The service-level contract: create / update / query through a
    // sharded service (resident delta replay on a pinned worker) returns
    // the same bits as the in-process session path, across a cold query,
    // warm queries over deltas, and a post-update warm query.
    let run = |shard_workers: usize| {
        let cfg = ServiceConfig {
            workers: 1,
            batcher: BatcherConfig { max_batch: 2, max_delay_us: 100, queue_depth: 16 },
            sinkhorn: SinkhornConfig { epsilon: 0.3, max_iters: 300, ..SinkhornConfig::default() },
            num_features: 32,
            shard_workers,
            ..ServiceConfig::default()
        };
        let svc = Service::start(cfg).unwrap();
        let h = svc.handle();
        let (mu, nu) = clouds(7, 40);
        let dim = mu.dim();
        let id = h.session_create(mu, nu, None).unwrap();
        let mut out = Vec::new();
        let q = h.session_query(id).unwrap();
        out.push((q.objective, q.iterations, q.warm_started));
        for chunk in op_log(21, dim, 6).chunks(6) {
            h.session_update(id, chunk).unwrap();
            let q = h.session_query(id).unwrap();
            out.push((q.objective, q.iterations, q.warm_started));
        }
        h.session_close(id).unwrap();
        drop(h);
        svc.shutdown();
        out
    };
    let local = run(0);
    let sharded = run(2);
    assert!(local.len() >= 3, "need a cold query plus >= 2 delta queries");
    for (l, s) in local.iter().zip(&sharded) {
        assert_eq!(l.0.to_bits(), s.0.to_bits(), "objective {l:?} vs {s:?}");
        assert_eq!(l.1, s.1, "iterations {l:?} vs {s:?}");
        assert_eq!(l.2, s.2, "warm flag {l:?} vs {s:?}");
    }
}
