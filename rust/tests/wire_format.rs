//! Wire-format exactness and robustness suite (`runtime::wire`,
//! `api::envelope`).
//!
//! The shard tier's bitwise contract rests on the wire round trip being
//! *exact*: every f32/f64 bit pattern a solve can produce — NaN payloads,
//! subnormals, signed zeros, infinities — must come back identical, and
//! every malformed frame must surface as a typed [`Error::Wire`], never a
//! panic and never a silently-wrong column.

use linear_sinkhorn::api::{OtProblem, Plan, TaskEnvelope};
use linear_sinkhorn::data::Measure;
use linear_sinkhorn::error::Error;
use linear_sinkhorn::linalg::Mat;
use linear_sinkhorn::runtime::WireDoc;
use linear_sinkhorn::rng::Rng;
use linear_sinkhorn::testing::{property, Gen};

/// Draw an f32 that is pathological with reasonable probability: NaNs
/// with varied payloads, subnormals, signed zeros, infinities, extremes,
/// and ordinary values.
fn nasty_f32(g: &mut Gen) -> f32 {
    match g.usize_in(0, 9) {
        0 => f32::from_bits(0x7FC0_0000 | g.rng.uniform_usize(1 << 22) as u32), // quiet NaN, payload
        1 => f32::from_bits(0xFF80_0001 | (g.rng.uniform_usize(1 << 20) as u32)), // negative NaN
        2 => f32::from_bits(g.rng.uniform_usize(0x0080_0000) as u32),           // +subnormal (or +0)
        3 => -f32::from_bits(g.rng.uniform_usize(0x0080_0000) as u32),          // -subnormal (or -0)
        4 => 0.0,
        5 => -0.0,
        6 => f32::INFINITY,
        7 => f32::NEG_INFINITY,
        8 => {
            if g.usize_in(0, 1) == 0 {
                f32::MAX
            } else {
                f32::MIN_POSITIVE
            }
        }
        _ => g.f64_in(-1e3, 1e3) as f32,
    }
}

fn nasty_f64(g: &mut Gen) -> f64 {
    match g.usize_in(0, 6) {
        0 => f64::from_bits(0x7FF8_0000_0000_0000 | g.rng.uniform_usize(1 << 30) as u64),
        1 => f64::from_bits(g.rng.uniform_usize(1 << 40) as u64), // deep subnormal
        2 => -0.0,
        3 => f64::INFINITY,
        4 => f64::MIN_POSITIVE,
        5 => g.f64_in(-1.0, 1.0),
        _ => f64::NEG_INFINITY,
    }
}

fn bits32(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn bits64(v: &[f64]) -> Vec<u64> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// A small valid plan to ride along in task envelopes (the executor never
/// cross-checks plan shapes against the shipped measures, and neither
/// does the codec — the plan is opaque cargo here).
fn carrier_plan() -> Plan {
    let mut rng = Rng::seed_from(3);
    let (mu, nu) = linear_sinkhorn::data::gaussian_blobs(10, &mut rng);
    OtProblem::new(&mu, &nu).epsilon(0.5).rank(8).seed(7).plan().unwrap()
}

#[test]
fn columns_round_trip_every_bit_pattern() {
    property("wire_columns_bit_exact", 48, |g| {
        let n32 = g.usize_in(0, 64);
        let n64 = g.usize_in(0, 64);
        let w32: Vec<f32> = (0..n32).map(|_| nasty_f32(g)).collect();
        let w64: Vec<f64> = (0..n64).map(|_| nasty_f64(g)).collect();
        let mut doc = WireDoc::with_kind("task");
        doc.set_u64("task_id", g.rng.uniform_usize(usize::MAX) as u64);
        doc.push_f32("w32", &w32).unwrap();
        doc.push_f64("w64", &w64).unwrap();
        let back = WireDoc::decode(&doc.encode()).expect("round trip");
        assert_eq!(bits32(back.f32s("w32").unwrap()), bits32(&w32), "f32 bits must survive");
        assert_eq!(bits64(back.f64s("w64").unwrap()), bits64(&w64), "f64 bits must survive");
    });
}

#[test]
fn task_envelopes_carry_pathological_weights_bitwise() {
    // The plan comes from clean measures; the shipped measures and weight
    // pairs are then replaced with pathological payloads. The codec must
    // not inspect values — only shapes — so every bit comes back.
    property("task_envelope_nasty_weights", 24, |g| {
        let n = g.usize_in(1, 12);
        let m = g.usize_in(1, 12);
        let dim = g.usize_in(1, 4);
        let mk = |g: &mut Gen, rows: usize| Measure {
            points: Mat::from_fn(rows, dim, |_, _| nasty_f32(g)),
            weights: (0..rows).map(|_| nasty_f32(g)).collect(),
        };
        let mu = mk(g, n);
        let nu = mk(g, m);
        let n_pairs = g.usize_in(0, 4);
        let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..n_pairs)
            .map(|_| {
                (
                    (0..n).map(|_| nasty_f32(g)).collect(),
                    (0..m).map(|_| nasty_f32(g)).collect(),
                )
            })
            .collect();
        let task = TaskEnvelope {
            task_id: g.rng.uniform_usize(usize::MAX) as u64,
            group_id: 1,
            request_ids: (0..n_pairs as u64).collect(),
            plan: carrier_plan(),
            mu,
            nu,
            pairs,
            map: None,
            session: None,
        };
        let back = TaskEnvelope::decode(&task.encode()).expect("round trip");
        assert_eq!(back.task_id, task.task_id);
        assert_eq!(back.request_ids, task.request_ids);
        assert_eq!(bits32(back.mu.points.data()), bits32(task.mu.points.data()));
        assert_eq!(bits32(&back.mu.weights), bits32(&task.mu.weights));
        assert_eq!(bits32(back.nu.points.data()), bits32(task.nu.points.data()));
        assert_eq!(bits32(&back.nu.weights), bits32(&task.nu.weights));
        assert_eq!(back.pairs.len(), task.pairs.len());
        for ((ba, bb), (ta, tb)) in back.pairs.iter().zip(&task.pairs) {
            assert_eq!(bits32(ba), bits32(ta));
            assert_eq!(bits32(bb), bits32(tb));
        }
    });
}

#[test]
fn empty_measures_round_trip() {
    let empty = Measure { points: Mat::from_vec(0, 2, vec![]), weights: vec![] };
    let task = TaskEnvelope {
        task_id: 9,
        group_id: 0,
        request_ids: vec![],
        plan: carrier_plan(),
        mu: empty.clone(),
        nu: empty,
        pairs: vec![],
        map: None,
        session: None,
    };
    let back = TaskEnvelope::decode(&task.encode()).expect("empty measures must round trip");
    assert_eq!(back.mu.len(), 0);
    assert_eq!(back.nu.len(), 0);
    assert!(back.pairs.is_empty());
    assert!(back.request_ids.is_empty());
}

#[test]
fn every_truncation_is_a_typed_error_never_a_panic() {
    let mut doc = WireDoc::with_kind("task");
    doc.set_u64("task_id", 1);
    doc.push_f32("w", &[1.0, f32::NAN, -0.0, 3.5]).unwrap();
    doc.push_f64("obj", &[0.25, f64::INFINITY]).unwrap();
    let frame = doc.encode();
    for cut in 0..frame.len() {
        match WireDoc::decode(&frame[..cut]) {
            Err(Error::Wire(_)) => {}
            Err(other) => panic!("truncation at {cut} must be Error::Wire, got {other}"),
            Ok(_) => panic!("truncation at {cut} decoded successfully"),
        }
    }
    assert!(WireDoc::decode(&frame).is_ok(), "the untruncated frame stays valid");
}

#[test]
fn header_payload_length_mismatches_are_rejected() {
    let mut doc = WireDoc::new();
    doc.push_f32("w", &[1.0, 2.0]).unwrap();
    let frame = doc.encode();

    // Declared header length shorter than the real header: the JSON
    // parser sees a prefix and the directory no longer matches the
    // payload. Either way: typed error.
    let mut short = frame.clone();
    let declared = u32::from_le_bytes(short[4..8].try_into().unwrap());
    short[4..8].copy_from_slice(&(declared - 1).to_le_bytes());
    assert!(matches!(WireDoc::decode(&short), Err(Error::Wire(_))));

    // Declared header length longer than the whole frame.
    let mut long = frame.clone();
    long[4..8].copy_from_slice(&(frame.len() as u32 * 2).to_le_bytes());
    assert!(matches!(WireDoc::decode(&long), Err(Error::Wire(_))));

    // Payload shorter than the directory claims.
    assert!(matches!(WireDoc::decode(&frame[..frame.len() - 4]), Err(Error::Wire(_))));

    // Payload longer than the directory claims.
    let mut padded = frame.clone();
    padded.extend_from_slice(&[0u8; 4]);
    assert!(matches!(WireDoc::decode(&padded), Err(Error::Wire(_))));
}

#[test]
fn random_byte_flips_never_panic() {
    // A flipped bit anywhere in the frame must yield either a clean
    // decode (payload flips change values, not structure) or a typed
    // error — never a panic, never an abort.
    property("wire_byte_flip_fuzz", 64, |g| {
        let mut doc = WireDoc::with_kind("result");
        doc.set_u64("task_id", 77);
        let vals: Vec<f32> = (0..g.usize_in(1, 32)).map(|_| nasty_f32(g)).collect();
        doc.push_f32("w", &vals).unwrap();
        let mut frame = doc.encode();
        let idx = g.rng.uniform_usize(frame.len());
        frame[idx] ^= 1 << g.usize_in(0, 7);
        match WireDoc::decode(&frame) {
            Ok(_) | Err(Error::Wire(_)) => {}
            Err(other) => panic!("byte flip at {idx} produced non-wire error {other}"),
        }
    });
}

#[test]
fn kind_confusion_is_rejected() {
    let task = TaskEnvelope {
        task_id: 1,
        group_id: 1,
        request_ids: vec![],
        plan: carrier_plan(),
        mu: Measure::uniform(Mat::ones(2, 2)),
        nu: Measure::uniform(Mat::ones(2, 2)),
        pairs: vec![],
        map: None,
        session: None,
    };
    let frame = task.encode();
    assert!(matches!(
        linear_sinkhorn::api::ResultEnvelope::decode(&frame),
        Err(Error::Wire(_))
    ));
    let ping = WireDoc::with_kind("ping").encode();
    assert!(matches!(TaskEnvelope::decode(&ping), Err(Error::Wire(_))));
}
