//! Integration tests for the PJRT runtime against the real AOT artifacts.
//!
//! These tests *skip* (pass trivially with a note) when `artifacts/` has
//! not been built — `make artifacts && cargo test` exercises them fully.
//! They verify the end-to-end claim: python lowered the graphs once, and
//! the Rust side reproduces the native implementation's numbers through
//! PJRT without any python at runtime.

use linear_sinkhorn::config::SinkhornConfig;
use linear_sinkhorn::features::{FeatureMap, GaussianFeatureMap};
use linear_sinkhorn::prelude::*;
use linear_sinkhorn::runtime::{mat_to_literal, vec_to_literal, Engine, Registry};
use linear_sinkhorn::sinkhorn::sinkhorn;

fn registry() -> Option<Registry> {
    // Tests run from the crate root.
    match Registry::load("artifacts") {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("SKIP (run `make artifacts`): {e}");
            None
        }
    }
}

#[test]
fn manifest_files_all_exist_and_hash() {
    let Some(reg) = registry() else { return };
    assert!(!reg.entries.is_empty());
    for meta in reg.entries.values() {
        let text = std::fs::read_to_string(&meta.file).expect("artifact file");
        assert!(text.starts_with("HloModule"), "{} is not HLO text", meta.name);
    }
}

#[test]
fn rf_sinkhorn_artifact_matches_native_solver() {
    let Some(reg) = registry() else { return };
    let Some(meta) = reg.find_prefix("rf_sinkhorn_n256") else {
        eprintln!("SKIP: no rf_sinkhorn_n256 artifact");
        return;
    };
    let n = meta.params[0].1[0];
    let r = meta.params[0].1[1];
    let iters = meta.constants["iters"] as usize;
    let eps = meta.constants["eps"];

    // Same positive factors on both paths.
    let mut rng = Rng::seed_from(42);
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, r, &mut rng);
    let phi_x = map.feature_matrix(&mu.points);
    let phi_y = map.feature_matrix(&nu.points);

    // Native: fixed iteration count to match the AOT graph exactly.
    let fk = FactoredKernel::from_factors(phi_x.clone(), phi_y.clone());
    let cfg = SinkhornConfig {
        epsilon: eps,
        max_iters: iters,
        tol: 0.0,
        check_every: iters + 1,
        ..Default::default()
    };
    let native = sinkhorn(&fk, &mu.weights, &nu.weights, &cfg).unwrap();

    // PJRT: run the lowered graph.
    let engine = Engine::cpu().expect("pjrt cpu");
    let exe = engine.load(meta).expect("compile");
    let outs = exe
        .run(&[
            mat_to_literal(&phi_x).unwrap(),
            mat_to_literal(&phi_y).unwrap(),
            vec_to_literal(&mu.weights),
            vec_to_literal(&nu.weights),
        ])
        .expect("execute");
    let u = outs[0].to_vec::<f32>().unwrap();
    let w_hat = outs[2].to_vec::<f32>().unwrap()[0] as f64;

    assert_eq!(u.len(), n);
    let rel = (w_hat - native.objective).abs() / native.objective.abs().max(1e-9);
    assert!(
        rel < 1e-3,
        "PJRT {w_hat} vs native {} (rel {rel:.2e})",
        native.objective
    );
    // Scalings agree elementwise (same iteration count, same arithmetic).
    for i in 0..n {
        let d = (u[i] - native.u[i]).abs() / native.u[i].abs().max(1e-9);
        assert!(d < 5e-3, "u[{i}]: pjrt {} native {}", u[i], native.u[i]);
    }
}

#[test]
fn dense_sinkhorn_artifact_matches_native() {
    let Some(reg) = registry() else { return };
    let Some(meta) = reg.find_prefix("dense_sinkhorn") else {
        eprintln!("SKIP: no dense artifact");
        return;
    };
    let n = meta.params[0].1[0];
    let iters = meta.constants["iters"] as usize;
    let eps = meta.constants["eps"];
    let mut rng = Rng::seed_from(1);
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    let dk = DenseKernel::from_measures(&mu, &nu, eps);
    let cfg = SinkhornConfig {
        epsilon: eps,
        max_iters: iters,
        tol: 0.0,
        check_every: iters + 1,
        ..Default::default()
    };
    let native = sinkhorn(&dk, &mu.weights, &nu.weights, &cfg).unwrap();

    let engine = Engine::cpu().unwrap();
    let exe = engine.load(meta).unwrap();
    let outs = exe
        .run(&[
            mat_to_literal(&dk.k).unwrap(),
            vec_to_literal(&mu.weights),
            vec_to_literal(&nu.weights),
        ])
        .unwrap();
    let w_hat = outs[2].to_vec::<f32>().unwrap()[0] as f64;
    let rel = (w_hat - native.objective).abs() / native.objective.abs().max(1e-9);
    assert!(rel < 1e-3, "PJRT {w_hat} vs native {}", native.objective);
}

#[test]
fn features_artifact_matches_native_feature_map() {
    let Some(reg) = registry() else { return };
    let Some(meta) = reg.find_prefix("rf_features_n256_r64_d2") else {
        eprintln!("SKIP: no features artifact");
        return;
    };
    let n = meta.params[0].1[0];
    let d = meta.params[0].1[1];
    let r = meta.params[1].1[0];
    let eps = meta.constants["eps"];
    let q = meta.constants["q"];
    let radius = meta.constants["radius"];

    let mut rng = Rng::seed_from(3);
    let x = Mat::from_fn(n, d, |_, _| (rng.normal() * 0.8) as f32);
    let sigma = (q * eps / 4.0).sqrt();
    let anchors = Mat::from_fn(r, d, |_, _| rng.normal_scaled(0.0, sigma) as f32);

    // Native features with the same (eps, q) constants.
    let map = GaussianFeatureMap::with_anchors(anchors.clone(), eps, q, radius);
    let native = map.feature_matrix(&x);

    let engine = Engine::cpu().unwrap();
    let exe = engine.load(meta).unwrap();
    let outs = exe
        .run(&[mat_to_literal(&x).unwrap(), mat_to_literal(&anchors).unwrap()])
        .unwrap();
    let phi = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(phi.len(), n * r);
    let mut max_rel = 0.0f64;
    for i in 0..n {
        for j in 0..r {
            let got = phi[i * r + j] as f64;
            let want = native[(i, j)] as f64;
            max_rel = max_rel.max((got - want).abs() / want.abs().max(1e-30));
        }
    }
    assert!(max_rel < 1e-3, "feature mismatch: max rel {max_rel:.2e}");
    // Positivity survives the AOT round-trip.
    assert!(phi.iter().all(|&v| v > 0.0));
}

#[test]
fn critic_grad_artifact_signs_and_shapes() {
    let Some(reg) = registry() else { return };
    let Some(meta) = reg.find_prefix("critic_grad") else {
        eprintln!("SKIP: no critic_grad artifact");
        return;
    };
    let s = meta.params[0].1[0];
    let r = meta.params[0].1[1];
    let mut rng = Rng::seed_from(4);
    let phi_x = Mat::from_fn(s, r, |_, _| (0.2 + rng.uniform() * 0.8) as f32);
    let phi_y = Mat::from_fn(s, r, |_, _| (0.2 + rng.uniform() * 0.8) as f32);
    let w = vec![1.0f32 / s as f32; s];
    let engine = Engine::cpu().unwrap();
    let exe = engine.load(meta).unwrap();
    let outs = exe
        .run(&[
            mat_to_literal(&phi_x).unwrap(),
            mat_to_literal(&phi_y).unwrap(),
            vec_to_literal(&w),
            vec_to_literal(&w),
        ])
        .unwrap();
    let gx = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(gx.len(), s * r);
    // Prop 3.2: the gradient through positive factors is elementwise <= 0.
    assert!(gx.iter().all(|&g| g <= 0.0), "critic grad must be non-positive");
}
