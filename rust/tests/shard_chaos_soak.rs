//! Chaos soak for the self-healing shard layer: multi-round seeded
//! storms of kills, flaps, stragglers, partitions, rejoins, overload,
//! and drains — with one invariant throughout: **every answered pair is
//! bitwise identical to the single-host fused solve**, and everything
//! unanswered fails typed (`Service` / `Wire` / `Overloaded`), never a
//! panic, never a wrong answer.
//!
//! Soak matrix (the healing rungs on top of
//! `rust/tests/shard_fault_injection.rs`'s classic ladder):
//!
//! | scenario                    | mechanism                          | expected                 |
//! |-----------------------------|------------------------------------|--------------------------|
//! | kill/flap/rejoin storm      | `inject_at` per incarnation        | rejoin, bitwise          |
//! | straggler hedging           | `Fault::SlowOnTask` + hedge cfg    | hedge win, bitwise       |
//! | partition then heal         | `Fault::Partition{Send,Recv}`      | retry absorbs, bitwise   |
//! | overload                    | `max_inflight_groups` exceeded     | typed `Overloaded` shed  |
//! | graceful drain mid-flight   | `drain()` racing a live group      | zero orphans, then typed |
//! | TCP worker crash + rejoin   | `spawn_tcp_worker_with` lives      | re-dial, bitwise         |
//! | mixed-version rejoiner      | `Fault::AdvertiseVersion`          | refused typed, survivors |
//! | seeded random soak rounds   | `FaultPlan::random` per round      | bitwise, every round     |
//!
//! Every schedule is deterministic given its seed, so a red run replays
//! exactly: `cargo test -q --test shard_chaos_soak` (or `make
//! shard-soak` for both SIMD arms).

use std::sync::Arc;
use std::time::{Duration, Instant};

use linear_sinkhorn::api::{DivergenceReport, OtProblem, Plan, PLAN_FORMAT_MAJOR};
use linear_sinkhorn::data::{self, Measure};
use linear_sinkhorn::error::{Error, Result};
use linear_sinkhorn::metrics::Registry;
use linear_sinkhorn::rng::Rng;
use linear_sinkhorn::shard::worker::{spawn_tcp_worker, spawn_tcp_worker_with};
use linear_sinkhorn::shard::{Fault, FaultPlan, ShardConfig, ShardCoordinator, WorkerOptions};

// ---------------------------------------------------------------- fixture

fn fixture(pairs: usize) -> (Measure, Measure, Vec<(Vec<f32>, Vec<f32>)>, Plan) {
    let mut rng = Rng::seed_from(61);
    let (mu, nu) = data::gaussian_blobs(14, &mut rng);
    let mut weights = Vec::with_capacity(pairs);
    for _ in 0..pairs {
        let mut a = rng.normal_vec(mu.len());
        let mut b = rng.normal_vec(nu.len());
        for w in a.iter_mut().chain(b.iter_mut()) {
            *w = w.abs() + 0.05;
        }
        let (sa, sb) = (a.iter().sum::<f32>(), b.iter().sum::<f32>());
        a.iter_mut().for_each(|w| *w /= sa);
        b.iter_mut().for_each(|w| *w /= sb);
        weights.push((a, b));
    }
    let refs: Vec<(&[f32], &[f32])> =
        weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    let plan = OtProblem::new(&mu, &nu)
        .epsilon(0.5)
        .rank(8)
        .seed(31)
        .weight_pairs(&refs)
        .plan()
        .unwrap();
    (mu, nu, weights, plan)
}

fn as_refs(weights: &[(Vec<f32>, Vec<f32>)]) -> Vec<(&[f32], &[f32])> {
    weights.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect()
}

fn local_baseline(
    mu: &Measure,
    nu: &Measure,
    refs: &[(&[f32], &[f32])],
    plan: &Plan,
) -> Vec<Result<DivergenceReport>> {
    OtProblem::new(mu, nu).weight_pairs(refs).divergence_all_planned(plan)
}

fn assert_bitwise(shard: &[Result<DivergenceReport>], local: &[Result<DivergenceReport>]) {
    assert_eq!(shard.len(), local.len());
    for (i, (s, l)) in shard.iter().zip(local).enumerate() {
        let s = s.as_ref().unwrap_or_else(|e| panic!("pair {i} failed over shards: {e}"));
        let l = l.as_ref().expect("local baseline must succeed");
        assert_eq!(s.divergence.to_bits(), l.divergence.to_bits(), "pair {i} divergence");
        assert_eq!(s.xy.objective.to_bits(), l.xy.objective.to_bits(), "pair {i} xy");
        assert_eq!(s.xx.objective.to_bits(), l.xx.objective.to_bits(), "pair {i} xx");
        assert_eq!(s.yy.objective.to_bits(), l.yy.objective.to_bits(), "pair {i} yy");
        assert_eq!(s.xy.u, l.xy.u, "pair {i} duals");
        assert_eq!(s.xy.iterations, l.xy.iterations, "pair {i} iterations");
    }
}

/// The soak baseline config: fast liveness, bounded retries, healing
/// rungs (hedging / rejoin) pinned off by default — each scenario turns
/// on exactly the rung it soaks.
fn soak_cfg() -> ShardConfig {
    ShardConfig {
        heartbeat_interval: Duration::from_millis(10),
        heartbeat_timeout: Duration::from_millis(300),
        task_deadline: Duration::from_millis(800),
        max_retries: 3,
        retry_backoff: Duration::from_millis(5),
        hedge_fraction: 0.0,
        max_inflight_groups: 16,
        rejoin_backoff: Duration::from_secs(60),
        ..ShardConfig::default()
    }
}

/// Pump rejoins until `want` workers are live (or a generous deadline
/// passes — the assertion then reports the real count).
fn heal(shard: &ShardCoordinator, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(5);
    while shard.live_workers() < want && Instant::now() < deadline {
        shard.pump_rejoins();
        std::thread::sleep(Duration::from_millis(10));
    }
}

// ------------------------------------------------------ kill/flap/rejoin

#[test]
fn kill_flap_rejoin_storm_stays_bitwise_every_round() {
    let (mu, nu, weights, plan) = fixture(6);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0 flaps: crashes on its first task in life 0 AND again in
    // life 1, serving cleanly only from life 2. Worker 1 crashes once.
    // Worker 2 never fails.
    let faults = FaultPlan::new(71)
        .inject_at(0, 0, Fault::KillOnTask { nth: 1 })
        .inject_at(0, 1, Fault::KillOnTask { nth: 1 })
        .inject_at(1, 0, Fault::KillOnTask { nth: 1 });
    let mut cfg = soak_cfg();
    cfg.rejoin_backoff = Duration::from_millis(150);
    let shard = ShardCoordinator::in_process_with_faults(3, cfg, metrics.clone(), &faults);

    // Round 0: two of three workers die mid-group; the survivor absorbs
    // their chunks through the retry ladder, bit for bit.
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert!(metrics.counter("service.shard.worker_deaths").get() >= 2);
    assert!(shard.live_workers() >= 1);

    // Heal: both dead slots rejoin after the backoff.
    std::thread::sleep(Duration::from_millis(160));
    heal(&shard, 3);
    assert_eq!(shard.live_workers(), 3, "fleet must heal to full strength");

    // Round 1: worker 0's rejoined life crashes again (the flap); the
    // other two carry the round, still bitwise.
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);

    // Heal again: worker 0's second rejoin is its clean life.
    std::thread::sleep(Duration::from_millis(160));
    heal(&shard, 3);
    assert_eq!(shard.live_workers(), 3);
    assert!(
        metrics.counter("service.shard.rejoins").get() >= 3,
        "w0 rejoined twice and w1 once: {}",
        metrics.render()
    );

    // Round 2: a fully healed fleet serves with no new faults.
    let deaths_before = metrics.counter("service.shard.worker_deaths").get();
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert_eq!(metrics.counter("service.shard.worker_deaths").get(), deaths_before);
    assert_eq!(shard.live_workers(), 3);
}

// ------------------------------------------------------------- hedging

#[test]
fn straggler_hedging_wins_without_changing_bits() {
    let (mu, nu, weights, plan) = fixture(1);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0 sits on its first solve for 800 ms while answering pings;
    // with a 2 s deadline and hedge fraction 0.1, the idle worker 1 gets
    // an identical copy after ~200 ms and wins the race. The primary is
    // never declared dead and no retry is burned — hedging is purely a
    // latency rung.
    let faults = FaultPlan::new(72)
        .inject(0, Fault::SlowOnTask { nth: 1, delay: Duration::from_millis(800) });
    let mut cfg = soak_cfg();
    cfg.task_deadline = Duration::from_secs(2);
    cfg.hedge_fraction = 0.1;
    let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);

    let start = Instant::now();
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    let elapsed = start.elapsed();
    assert_bitwise(&got, &local);
    assert!(metrics.counter("service.shard.hedged_tasks").get() >= 1, "{}", metrics.render());
    assert!(metrics.counter("service.shard.hedge_wins").get() >= 1, "{}", metrics.render());
    assert_eq!(metrics.counter("service.shard.retries").get(), 0);
    assert_eq!(metrics.counter("service.shard.worker_deaths").get(), 0);
    assert_eq!(shard.live_workers(), 2);
    assert!(
        elapsed < Duration::from_millis(800),
        "the hedge must beat the {} ms straggler (took {elapsed:?})",
        800
    );
}

// ------------------------------------------------------------ partitions

#[test]
fn partition_windows_heal_via_retry_bitwise() {
    let (mu, nu, weights, plan) = fixture(2);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    // Outbound partition: worker 0's task frame dies in flight (the
    // coordinator believes it sent). The task deadline re-scatters to
    // worker 1.
    let metrics = Arc::new(Registry::default());
    let faults = FaultPlan::new(73).inject(0, Fault::PartitionSend { from: 0, count: 1 });
    let mut cfg = soak_cfg();
    cfg.task_deadline = Duration::from_millis(250);
    let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert!(metrics.counter("service.shard.retries").get() >= 1, "{}", metrics.render());

    // Inbound partition: worker 0 solves and answers, but the result dies
    // in the window (read off the link, never delivered — unlike a
    // delay). Same healing: deadline, retry, bitwise.
    let metrics = Arc::new(Registry::default());
    let faults = FaultPlan::new(74).inject(0, Fault::PartitionRecv { from: 0, count: 1 });
    let mut cfg = soak_cfg();
    cfg.task_deadline = Duration::from_millis(250);
    let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert!(metrics.counter("service.shard.retries").get() >= 1, "{}", metrics.render());
}

// -------------------------------------------------------------- overload

#[test]
fn overload_sheds_typed_and_recovers() {
    let (mu, nu, weights, plan) = fixture(1);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Budget of one in-flight group, and a worker slow enough to hold
    // that budget while we poke the admission gate from outside.
    let faults = FaultPlan::new(75)
        .inject(0, Fault::SlowOnTask { nth: 1, delay: Duration::from_millis(400) });
    let mut cfg = soak_cfg();
    cfg.task_deadline = Duration::from_secs(5);
    cfg.max_inflight_groups = 1;
    let shard = Arc::new(ShardCoordinator::in_process_with_faults(
        1,
        cfg,
        metrics.clone(),
        &faults,
    ));

    let slow = {
        let shard = Arc::clone(&shard);
        let (mu, nu, plan) = (mu.clone(), nu.clone(), plan.clone());
        let weights = weights.clone();
        std::thread::spawn(move || {
            let refs = as_refs(&weights);
            shard.solve_group(&plan, &mu, &nu, &refs, None, &[])
        })
    };
    // Wait until the slow group is actually admitted...
    let deadline = Instant::now() + Duration::from_secs(2);
    while shard.inflight_groups() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(shard.inflight_groups(), 1, "slow group must be in flight");
    // ...then the budget is full: the next group sheds typed, instantly,
    // without touching a worker.
    let shed = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    for slot in &shed {
        assert!(
            matches!(slot, Err(Error::Overloaded(_))),
            "expected typed overload shed, got {slot:?}"
        );
    }
    assert!(metrics.counter("service.shard.shed_groups").get() >= 1);

    // The shed never corrupted the in-flight group: it completes bitwise.
    let slow = slow.join().expect("slow solver thread");
    assert_bitwise(&slow, &local);
    assert_eq!(shard.inflight_groups(), 0);

    // And with the budget free again, the coordinator serves once more.
    let again = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&again, &local);
}

// ----------------------------------------------------------------- drain

#[test]
fn drain_mid_flight_finishes_work_then_refuses() {
    let (mu, nu, weights, plan) = fixture(2);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // One straggling solve keeps a group in flight while drain() arrives:
    // phase 1 must wait it out (zero orphaned tasks), then the workers
    // acknowledge and exit.
    let faults = FaultPlan::new(76)
        .inject(0, Fault::SlowOnTask { nth: 1, delay: Duration::from_millis(300) });
    let mut cfg = soak_cfg();
    cfg.task_deadline = Duration::from_secs(5);
    let shard = Arc::new(ShardCoordinator::in_process_with_faults(
        2,
        cfg,
        metrics.clone(),
        &faults,
    ));

    let inflight = {
        let shard = Arc::clone(&shard);
        let (mu, nu, plan) = (mu.clone(), nu.clone(), plan.clone());
        let weights = weights.clone();
        std::thread::spawn(move || {
            let refs = as_refs(&weights);
            shard.solve_group(&plan, &mu, &nu, &refs, None, &[])
        })
    };
    let deadline = Instant::now() + Duration::from_secs(2);
    while shard.inflight_groups() == 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert_eq!(shard.inflight_groups(), 1);

    let acked = shard.drain(Duration::from_secs(10)).expect("drain within deadline");
    assert_eq!(acked, 2, "both workers must acknowledge the drain");
    assert_eq!(metrics.counter("service.shard.drained_workers").get(), 2);

    // The in-flight group was never orphaned: every pair answered,
    // bitwise.
    let inflight = inflight.join().expect("in-flight solver thread");
    assert_bitwise(&inflight, &local);

    // Drained is terminal: new groups refuse typed, nobody rejoins.
    let after = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert!(matches!(&after[0], Err(Error::Service(_))), "{:?}", after[0]);
    assert_eq!(shard.pump_rejoins(), 0);
    assert_eq!(shard.live_workers(), 0);
    assert_eq!(
        metrics.counter("service.shard.worker_deaths").get(),
        0,
        "drain retires workers, it does not kill them"
    );
}

// ------------------------------------------------------------ TCP rejoin

#[test]
fn tcp_worker_crashes_then_rejoins_over_a_fresh_connection() {
    let (mu, nu, weights, plan) = fixture(2);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    // Worker 0 serves two connection lives: the first crashes on its
    // first task, the second is clean — exactly what a supervised
    // `shard-worker` process restart looks like from the coordinator.
    let crashy = WorkerOptions { exit_on_task: Some(1), ..WorkerOptions::default() };
    let (addr_a, join_a) =
        spawn_tcp_worker_with(0, vec![crashy, WorkerOptions::default()]).unwrap();
    let (addr_b, join_b) = spawn_tcp_worker(1).unwrap();

    let metrics = Arc::new(Registry::default());
    let mut cfg = soak_cfg();
    cfg.rejoin_backoff = Duration::from_millis(20);
    let shard = ShardCoordinator::connect(
        &[addr_a.to_string(), addr_b.to_string()],
        cfg,
        metrics.clone(),
    )
    .unwrap();

    // Round 0: the crash drops the link; the survivor absorbs the chunk.
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);
    assert!(metrics.counter("service.shard.worker_deaths").get() >= 1);

    // Heal: the coordinator re-dials the same roster address; the
    // listener's second life answers the handshake and rejoins.
    heal(&shard, 2);
    assert_eq!(shard.live_workers(), 2, "TCP worker must rejoin: {}", metrics.render());
    assert!(metrics.counter("service.shard.rejoins").get() >= 1);

    // Round 1: the rejoined fleet serves bitwise again.
    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);

    drop(shard); // shutdown frames / closed links end both workers' lives
    join_a.join().unwrap();
    join_b.join().unwrap();
}

// --------------------------------------------------------- mixed version

#[test]
fn mixed_version_rejoiner_is_refused_typed_and_survivors_serve() {
    let (mu, nu, weights, plan) = fixture(2);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    let metrics = Arc::new(Registry::default());
    // Worker 0 crashes, and its rejoined life comes back speaking a
    // different plan format major — a half-upgraded fleet. The handshake
    // must refuse it (it would mis-decode tasks), count the failure, and
    // keep serving on the survivor.
    let faults = FaultPlan::new(77)
        .inject_at(0, 0, Fault::KillOnTask { nth: 1 })
        .inject_at(0, 1, Fault::AdvertiseVersion { major: PLAN_FORMAT_MAJOR as u64 + 1 });
    let mut cfg = soak_cfg();
    cfg.rejoin_backoff = Duration::from_millis(20);
    let shard = ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);

    let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&got, &local);

    // Give the rejoin machinery several chances: the wrong-version life
    // must never be admitted.
    for _ in 0..5 {
        std::thread::sleep(Duration::from_millis(25));
        shard.pump_rejoins();
    }
    assert_eq!(shard.live_workers(), 1, "mixed-version rejoiner must stay out");
    assert!(
        metrics.counter("service.shard.rejoin_failures").get() >= 1,
        "{}",
        metrics.render()
    );
    assert_eq!(metrics.counter("service.shard.rejoins").get(), 0);

    // The surviving worker keeps answering, bitwise.
    let again = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
    assert_bitwise(&again, &local);
}

// ------------------------------------------------------------ seeded soak

#[test]
fn seeded_random_soak_rounds_stay_bitwise() {
    let (mu, nu, weights, plan) = fixture(4);
    let refs = as_refs(&weights);
    let local = local_baseline(&mu, &nu, &refs, &plan);

    // Multi-round soak: each round layers a fresh seeded schedule of
    // survivable message faults (drops, delays, duplicates) over a kill
    // + rejoin cycle. Whatever the round throws, every answered pair
    // must carry the single-host bits.
    for round in 0..4u64 {
        let faults = FaultPlan::random(100 + round, 2, 3)
            .inject_at(0, 0, Fault::KillOnTask { nth: 1 });
        let mut cfg = soak_cfg();
        cfg.max_retries = 5; // kills + random drops stack; keep headroom
        cfg.task_deadline = Duration::from_millis(400);
        cfg.rejoin_backoff = Duration::from_millis(30);
        let metrics = Arc::new(Registry::default());
        let shard =
            ShardCoordinator::in_process_with_faults(2, cfg, metrics.clone(), &faults);

        let got = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&got, &local);

        // The killed worker heals and the next group uses the full
        // fleet, still bitwise.
        heal(&shard, 2);
        assert_eq!(shard.live_workers(), 2, "round {round}: {}", metrics.render());
        let again = shard.solve_group(&plan, &mu, &nu, &refs, None, &[]);
        assert_bitwise(&again, &local);
        assert!(
            metrics.counter("service.shard.rejoins").get() >= 1,
            "round {round}: {}",
            metrics.render()
        );
    }
}
