//! Planner-equivalence properties: every [`Plan`] the planner can emit
//! must execute **bitwise identically** to the corresponding hand-wired
//! legacy free-function call (the acceptance criterion of the API
//! redesign). The mapping under test is the table in
//! `rust/src/api/execute.rs`:
//!
//! * dense / factored backend × plain / log-domain / auto-escalate domain,
//! * B ∈ {1, 4} weight pairs (fused batched execution),
//! * 1 vs 4 solver threads (pool transparency through the API),
//! * prebuilt-factor problems (the GAN path) and seeded internal fits.
//!
//! SIMD arms: these properties run under whatever arm the process
//! dispatches; CI runs the whole suite twice (default + the
//! `verify-scalar` job with `LINEAR_SINKHORN_SIMD=scalar`), which is what
//! "both arms" means everywhere in this repo — the arm is process-global
//! by design.

use linear_sinkhorn::config::SinkhornConfig;
use linear_sinkhorn::prelude::*;
// The reference layer the planned executor must reproduce bit for bit
// (re-exported for downstream users as prelude::legacy).
use linear_sinkhorn::sinkhorn::{
    sinkhorn, sinkhorn_accelerated, sinkhorn_divergence, sinkhorn_log_domain, sinkhorn_stabilized,
    solve_batch, solve_batch_log_domain, solve_batch_stabilized,
};

fn clouds(seed: u64, n: usize) -> (Measure, Measure) {
    let mut rng = Rng::seed_from(seed);
    data::gaussian_blobs(n, &mut rng)
}

fn cfg(eps: f64) -> SinkhornConfig {
    SinkhornConfig {
        epsilon: eps,
        max_iters: 400,
        tol: 1e-5,
        check_every: 5,
        threads: 1,
        stabilize: false,
        max_batch: 8,
        anneal: None,
        anneal_decay: 0.5,
        symmetric: None,
    }
}

/// B skewed weight vectors of length n, each summing to one.
fn weight_family(n: usize, b: usize) -> Vec<Vec<f32>> {
    (0..b)
        .map(|k| {
            let raw: Vec<f64> = (0..n)
                .map(|i| 1.0 + ((i * (k + 2) + k) % 7) as f64 * (0.2 + k as f64 * 0.3))
                .collect();
            let total: f64 = raw.iter().sum();
            raw.iter().map(|&x| (x / total) as f32).collect()
        })
        .collect()
}

fn assert_solution_matches(api: &Solution, legacy: &linear_sinkhorn::sinkhorn::SinkhornSolution) {
    assert_eq!(api.objective.to_bits(), legacy.objective.to_bits(), "objective");
    assert_eq!(api.iterations, legacy.iterations, "iterations");
    assert_eq!(api.converged, legacy.converged, "converged");
    assert_eq!(api.marginal_error.to_bits(), legacy.marginal_error.to_bits(), "marginal");
    assert_eq!(api.u.len(), legacy.u.len());
    for (i, (a, l)) in api.u.iter().zip(&legacy.u).enumerate() {
        assert_eq!(a.to_bits(), l.to_bits(), "u[{i}]");
    }
    for (j, (a, l)) in api.v.iter().zip(&legacy.v).enumerate() {
        assert_eq!(a.to_bits(), l.to_bits(), "v[{j}]");
    }
}

#[test]
fn dense_plain_plan_matches_direct_dense_sinkhorn() {
    let (mu, nu) = clouds(0, 60);
    let c = cfg(0.5);
    let api = OtProblem::new(&mu, &nu).config(&c).dense().solve().unwrap();
    let dk = DenseKernel::from_measures(&mu, &nu, 0.5);
    let legacy = sinkhorn(&dk, &mu.weights, &nu.weights, &c).unwrap();
    assert_solution_matches(&api, &legacy);
    assert!(!api.escalated);
}

#[test]
fn factored_plain_plan_matches_direct_factored_sinkhorn() {
    // Map shared explicitly: the planned route and the hand-wired route
    // must then agree bit for bit (same factors, same solver loop).
    let (mu, nu) = clouds(1, 50);
    let c = cfg(0.5);
    let mut rng = Rng::seed_from(11);
    let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 64, &mut rng);
    let api = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(64)
        .with_feature_map(&map)
        .stabilized_factors(false)
        .solve()
        .unwrap();
    let fk = FactoredKernel::from_measures(&map, &mu, &nu);
    let legacy = sinkhorn(&fk, &mu.weights, &nu.weights, &c).unwrap();
    assert_solution_matches(&api, &legacy);
}

#[test]
fn seeded_internal_fit_matches_a_seeded_external_fit() {
    // No map handed in: the executor's documented draw is
    // GaussianFeatureMap::fit(.., &mut Rng::seed_from(seed)) — replicate
    // it externally and the results must be bitwise identical.
    let (mu, nu) = clouds(2, 40);
    let c = cfg(0.5);
    let api = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(32)
        .stabilized_factors(false)
        .seed(77)
        .solve()
        .unwrap();
    let mut rng = Rng::seed_from(77);
    let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 32, &mut rng);
    let fk = FactoredKernel::from_measures(&map, &mu, &nu);
    let legacy = sinkhorn(&fk, &mu.weights, &nu.weights, &c).unwrap();
    assert_solution_matches(&api, &legacy);
}

#[test]
fn log_domain_plan_matches_direct_log_domain_solver() {
    let (mu, nu) = clouds(3, 30);
    let eps = 1e-2;
    let c = SinkhornConfig { max_iters: 120, ..cfg(eps) };
    let mut rng = Rng::seed_from(13);
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 24, &mut rng);
    let api = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(24)
        .with_feature_map(&map)
        .stabilized_factors(true)
        .domain(DomainChoice::LogDomain)
        .solve()
        .unwrap();
    let fk = FactoredKernel::from_measures_stabilized(&map, &mu, &nu);
    let legacy = sinkhorn_log_domain(&fk, &mu.weights, &nu.weights, &c).unwrap();
    assert_solution_matches(&api, &legacy);
    assert!(!api.escalated, "a planned log domain is not an escalation");
}

#[test]
fn auto_escalate_plan_matches_sinkhorn_stabilized_on_underflowing_factors() {
    // Factors near 1e-30: plain f32 provably diverges and escalates.
    let (n, m) = (12, 10);
    let phi_x = Mat::from_fn(n, 6, |i, k| 1e-30f32 * (1.0 + 0.1 * (((i + 2 * k) % 5) as f32)));
    let phi_y = Mat::from_fn(m, 6, |j, k| 1e-30f32 * (1.0 + 0.1 * (((2 * j + k) % 7) as f32)));
    let a = weight_family(n, 1).remove(0);
    let b = weight_family(m, 1).remove(0);
    let c = SinkhornConfig { stabilize: true, ..cfg(1e-3) };
    let api = OtProblem::from_factors(&phi_x, &phi_y)
        .config(&c)
        .weights(&a, &b)
        .solve()
        .unwrap();
    let fk = FactoredKernel::from_factors(phi_x.clone(), phi_y.clone());
    let (legacy, escalated) = sinkhorn_stabilized(&fk, &a, &b, &c).unwrap();
    assert!(escalated && api.escalated, "both routes must take the log-domain path");
    assert_solution_matches(&api, &legacy);
    // With the plain domain the typed error surfaces through the API too.
    let plain = SinkhornConfig { stabilize: false, ..c };
    let err = OtProblem::from_factors(&phi_x, &phi_y).config(&plain).weights(&a, &b).solve();
    assert!(matches!(err, Err(Error::SinkhornDiverged { .. })));
}

#[test]
fn batched_plans_match_solve_batch_per_pair_bitwise() {
    // B = 4 on one kernel: the planned fused execution must reproduce
    // both the legacy batched call and B solo solves, bit for bit.
    let (mu, nu) = clouds(4, 35);
    let c = cfg(0.5);
    let mut rng = Rng::seed_from(17);
    let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 48, &mut rng);
    let ws_a = weight_family(mu.len(), 4);
    let ws_b = weight_family(nu.len(), 4);
    let pairs: Vec<(&[f32], &[f32])> =
        ws_a.iter().zip(&ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    let api = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(48)
        .with_feature_map(&map)
        .stabilized_factors(false)
        .weight_pairs(&pairs)
        .solve_all();
    assert_eq!(api.len(), 4);
    let fk = FactoredKernel::from_measures(&map, &mu, &nu);
    let legacy = solve_batch(&fk, &pairs, &c);
    for (p, (got, want)) in api.iter().zip(&legacy).enumerate() {
        let (got, want) = (got.as_ref().unwrap(), want.as_ref().unwrap());
        assert_solution_matches(got, want);
        let solo = sinkhorn(&fk, pairs[p].0, pairs[p].1, &c).unwrap();
        assert_solution_matches(got, &solo);
    }
    // B = 1 degenerates to the single-solve route exactly.
    let single: Vec<(&[f32], &[f32])> = vec![pairs[0]];
    let one = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(48)
        .with_feature_map(&map)
        .stabilized_factors(false)
        .weight_pairs(&single)
        .solve_all();
    assert_solution_matches(
        one[0].as_ref().unwrap(),
        &sinkhorn(&fk, pairs[0].0, pairs[0].1, &c).unwrap(),
    );
}

#[test]
fn batched_log_domain_plan_matches_solve_batch_log_domain() {
    let (mu, nu) = clouds(5, 20);
    let eps = 1e-2;
    let c = SinkhornConfig { max_iters: 80, ..cfg(eps) };
    let mut rng = Rng::seed_from(19);
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 16, &mut rng);
    let ws_a = weight_family(mu.len(), 3);
    let ws_b = weight_family(nu.len(), 3);
    let pairs: Vec<(&[f32], &[f32])> =
        ws_a.iter().zip(&ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    let api = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(16)
        .with_feature_map(&map)
        .stabilized_factors(true)
        .domain(DomainChoice::LogDomain)
        .weight_pairs(&pairs)
        .solve_all();
    let fk = FactoredKernel::from_measures_stabilized(&map, &mu, &nu);
    let legacy = solve_batch_log_domain(&fk, &pairs, &c);
    for (got, want) in api.iter().zip(&legacy) {
        assert_solution_matches(got.as_ref().unwrap(), want.as_ref().unwrap());
    }
}

#[test]
fn divergence_plan_matches_legacy_sinkhorn_divergence() {
    let (mu, nu) = clouds(6, 40);
    let c = cfg(0.5);
    let mut rng = Rng::seed_from(23);
    let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 48, &mut rng);
    let report = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(48)
        .with_feature_map(&map)
        .stabilized_factors(false)
        .divergence()
        .unwrap();
    let k_xy = FactoredKernel::from_measures(&map, &mu, &nu);
    let k_xx = FactoredKernel::from_measures(&map, &mu, &mu);
    let k_yy = FactoredKernel::from_measures(&map, &nu, &nu);
    let legacy =
        sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &c).unwrap();
    assert_eq!(report.divergence.to_bits(), legacy.to_bits());
    assert_eq!(report.escalations(), 0);
}

#[test]
fn divergence_from_factors_matches_the_gan_triple() {
    // The GAN path: three plain solves on prebuilt factors.
    let mut rng = Rng::seed_from(29);
    let (mu, nu) = clouds(7, 24);
    let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 16, &mut rng);
    let phi_a = map.feature_matrix(&mu.points);
    let phi_b = map.feature_matrix(&nu.points);
    let s = mu.len();
    let w = vec![1.0f32 / s as f32; s];
    let c = cfg(0.5);
    let report = OtProblem::from_factors(&phi_a, &phi_b)
        .config(&c)
        .weights(&w, &w)
        .divergence()
        .unwrap();
    let k_xy = FactoredKernel::from_factors(phi_a.clone(), phi_b.clone());
    let k_xx = FactoredKernel::from_factors(phi_a.clone(), phi_a.clone());
    let k_yy = FactoredKernel::from_factors(phi_b.clone(), phi_b.clone());
    let s_xy = sinkhorn(&k_xy, &w, &w, &c).unwrap();
    let s_xx = sinkhorn(&k_xx, &w, &w, &c).unwrap();
    let s_yy = sinkhorn(&k_yy, &w, &w, &c).unwrap();
    assert_solution_matches(&report.xy, &s_xy);
    assert_solution_matches(&report.xx, &s_xx);
    assert_solution_matches(&report.yy, &s_yy);
    let div = s_xy.objective - 0.5 * (s_xx.objective + s_yy.objective);
    assert_eq!(report.divergence.to_bits(), div.to_bits());
}

#[test]
fn batched_divergence_plan_matches_solve_batch_stabilized_triple() {
    // The coordinator fuse-group path: three width-B batched solves.
    let (mu, nu) = clouds(8, 30);
    let c = SinkhornConfig { stabilize: true, ..cfg(0.5) };
    let mut rng = Rng::seed_from(31);
    let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 32, &mut rng);
    let ws_a = weight_family(mu.len(), 4);
    let ws_b = weight_family(nu.len(), 4);
    let pairs: Vec<(&[f32], &[f32])> =
        ws_a.iter().zip(&ws_b).map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    let reports = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(32)
        .with_feature_map(&map)
        .stabilized_factors(true)
        .weight_pairs(&pairs)
        .divergence_all();
    let k_xy = FactoredKernel::from_measures_stabilized(&map, &mu, &nu);
    let k_xx = FactoredKernel::from_measures_stabilized(&map, &mu, &mu);
    let k_yy = FactoredKernel::from_measures_stabilized(&map, &nu, &nu);
    let xx_pairs: Vec<(&[f32], &[f32])> = pairs.iter().map(|&(a, _)| (a, a)).collect();
    let yy_pairs: Vec<(&[f32], &[f32])> = pairs.iter().map(|&(_, b)| (b, b)).collect();
    let l_xy = solve_batch_stabilized(&k_xy, &pairs, &c);
    let l_xx = solve_batch_stabilized(&k_xx, &xx_pairs, &c);
    let l_yy = solve_batch_stabilized(&k_yy, &yy_pairs, &c);
    for (p, report) in reports.iter().enumerate() {
        let report = report.as_ref().unwrap();
        let (xy, _) = l_xy[p].as_ref().unwrap();
        let (xx, _) = l_xx[p].as_ref().unwrap();
        let (yy, _) = l_yy[p].as_ref().unwrap();
        assert_solution_matches(&report.xy, xy);
        let div = xy.objective - 0.5 * (xx.objective + yy.objective);
        assert_eq!(report.divergence.to_bits(), div.to_bits(), "pair {p}");
    }
}

#[test]
fn solver_threads_are_transparent_through_the_api() {
    // 1 vs 4 intra-solve threads and 1 vs 3 solve threads: identical bits
    // (n = 700 crosses the pooled-matvec and parallel-feature thresholds).
    let (mu, nu) = clouds(9, 700);
    let c = SinkhornConfig { max_iters: 60, stabilize: true, ..cfg(0.5) };
    let run = |solver_threads: usize, threads: usize| {
        OtProblem::new(&mu, &nu)
            .config(&c)
            .rank(64)
            .seed(5)
            .threads(threads)
            .solver_threads(solver_threads)
            .divergence()
            .unwrap()
            .divergence
    };
    let d11 = run(1, 1);
    let d41 = run(4, 1);
    let d13 = run(1, 3);
    let d43 = run(4, 3);
    assert_eq!(d11.to_bits(), d41.to_bits(), "solver threads changed the bits");
    assert_eq!(d11.to_bits(), d13.to_bits(), "solve threads changed the bits");
    assert_eq!(d11.to_bits(), d43.to_bits(), "combined threading changed the bits");
}

#[test]
fn accelerated_plan_matches_direct_sinkhorn_accelerated() {
    let (mu, nu) = clouds(10, 40);
    let c = SinkhornConfig { max_iters: 200, check_every: 1, ..cfg(0.5) };
    let mut rng = Rng::seed_from(37);
    let map = GaussianFeatureMap::fit(&mu, &nu, 0.5, 32, &mut rng);
    let api = OtProblem::new(&mu, &nu)
        .config(&c)
        .rank(32)
        .with_feature_map(&map)
        .stabilized_factors(false)
        .domain(DomainChoice::Plain)
        .accelerated()
        .solve()
        .unwrap();
    let fk = FactoredKernel::from_measures(&map, &mu, &nu);
    let legacy = sinkhorn_accelerated(&fk, &mu.weights, &nu.weights, &c).unwrap();
    assert_eq!(api.objective.to_bits(), legacy.objective.to_bits());
    assert_eq!(api.iterations, legacy.iterations);
    assert_eq!(
        api.grad_norm.unwrap().to_bits(),
        legacy.grad_norm.to_bits(),
        "accelerated diagnostics"
    );
}

#[test]
fn executed_plan_round_trips_through_json_identically() {
    // Serialise the plan, decode it, execute both: identical bits — the
    // property cross-host shard dispatch will rely on.
    let (mu, nu) = clouds(12, 45);
    let problem = OtProblem::new(&mu, &nu).epsilon(0.25).rank(40).seed(3);
    let plan = problem.plan().unwrap();
    let decoded = Plan::from_json(&plan.to_json()).unwrap();
    assert_eq!(decoded, plan);
    let a = problem.solve_planned(&plan).unwrap();
    let b = problem.solve_planned(&decoded).unwrap();
    assert_solution_matches_api(&a, &b);
    let da = problem.divergence_planned(&plan).unwrap();
    let db = problem.divergence_planned(&decoded).unwrap();
    assert_eq!(da.divergence.to_bits(), db.divergence.to_bits());
}

fn assert_solution_matches_api(a: &Solution, b: &Solution) {
    assert_eq!(a.objective.to_bits(), b.objective.to_bits());
    assert_eq!(a.iterations, b.iterations);
    for (x, y) in a.u.iter().zip(&b.u) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
    for (x, y) in a.v.iter().zip(&b.v) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

#[test]
fn nystrom_plan_round_trips_json_and_executes_bitwise() {
    // PR-8 acceptance: a Nyström plan survives serialisation (adaptive
    // flag included) and the decoded plan executes bit-for-bit — the
    // landmark draw is a pure function of the plan seed, so the decoded
    // side rebuilds the identical kernel with no shipped artifact.
    let (mu, nu) = clouds(14, 50);
    for adaptive in [false, true] {
        let problem = OtProblem::new(&mu, &nu)
            .epsilon(5.0)
            .backend(BackendPref::Nystrom { rank: 10, adaptive })
            .seed(9);
        let plan = problem.plan().unwrap();
        assert_eq!(plan.backend, Backend::Nystrom { rank: 10, adaptive });
        let decoded = Plan::from_json(&plan.to_json()).unwrap();
        assert_eq!(decoded, plan, "adaptive={adaptive}");
        let a = problem.solve_planned(&plan).unwrap();
        let b = problem.solve_planned(&decoded).unwrap();
        assert_solution_matches_api(&a, &b);
        let da = problem.divergence_planned(&plan).unwrap();
        let db = problem.divergence_planned(&decoded).unwrap();
        assert_eq!(da.divergence.to_bits(), db.divergence.to_bits(), "adaptive={adaptive}");
        assert!(da.divergence.is_finite());
    }
}

#[test]
fn nystrom_solver_threads_are_transparent_through_the_api() {
    // Pool transparency holds for the new backend too: 1 vs 4 intra-solve
    // threads and 1 vs 3 solve threads produce identical bits (n = 700
    // crosses the pooled-matvec chunk threshold, so the pooled apply path
    // actually engages).
    let (mu, nu) = clouds(15, 700);
    let run = |solver_threads: usize, threads: usize, adaptive: bool| {
        OtProblem::new(&mu, &nu)
            .epsilon(5.0)
            .backend(BackendPref::Nystrom { rank: 24, adaptive })
            .seed(6)
            .max_iters(60)
            .threads(threads)
            .solver_threads(solver_threads)
            .divergence()
            .unwrap()
            .divergence
    };
    for adaptive in [false, true] {
        let d11 = run(1, 1, adaptive);
        let d41 = run(4, 1, adaptive);
        let d43 = run(4, 3, adaptive);
        assert_eq!(d11.to_bits(), d41.to_bits(), "solver threads changed the bits");
        assert_eq!(d11.to_bits(), d43.to_bits(), "combined threading changed the bits");
    }
}
