//! Parallel-vs-serial equivalence: the intra-solve execution layer
//! (`runtime::pool` + the `_pooled` linalg kernels + the concurrent
//! three-problem divergence) must change wall-clock only, never numbers.
//!
//! Four layers of guarantee are asserted here:
//! 1. `matvec_into_pooled` is **bitwise** equal to `matvec_into` (rows are
//!    independent and share the per-row kernel).
//! 2. `matvec_t_into_pooled` is **thread-count invariant** (fixed chunk
//!    grid, ordered f64 reduce) and agrees with the serial kernel and an
//!    f64 reference to well under 1e-5 relative even at n = 5000 — the
//!    reorder only moves f32 rounding, it cannot cancel on the positive
//!    data Sinkhorn feeds it.
//! 3. The pooled logsumexp primitives (`lse_matvec_into_pooled`,
//!    `lse_matvec_t_into_pooled`) behind the log-domain solver obey the
//!    same contract: bitwise thread-count invariance on a fixed chunk
//!    grid, and near-f64-reference accuracy through the chunked merge.
//! 4. `sinkhorn_divergence` returns bit-identical objectives with 1 and N
//!    threads, at both the solve level (`cfg.threads`) and the matvec
//!    level (kernel pools).
//! 5. Since the SIMD core landed, guarantees 1–3 hold **per dispatch
//!    arm** (the `*_at` entry points pin scalar vs AVX2+FMA), and the
//!    two arms agree within the documented kernel tolerances at sizes
//!    that straddle every lane boundary — including empty and
//!    single-row mats (`simd_arms_*` tests below).

use linear_sinkhorn::config::SinkhornConfig;
use linear_sinkhorn::features::{par_feature_matrix, par_log_feature_matrix};
use linear_sinkhorn::linalg::simd::{active_level, SimdLevel};
use linear_sinkhorn::linalg::{
    lse_matvec_into, lse_matvec_into_at, lse_matvec_into_pooled, lse_matvec_into_pooled_at,
    lse_matvec_t_into, lse_matvec_t_into_at, lse_matvec_t_into_pooled,
    lse_matvec_t_into_pooled_at, matvec_into, matvec_into_at, matvec_into_pooled,
    matvec_into_pooled_at, matvec_t_into, matvec_t_into_at, matvec_t_into_pooled,
    matvec_t_into_pooled_at, Mat,
};
use linear_sinkhorn::prelude::*;
// The reference free-function layer under test (prelude::legacy).
use linear_sinkhorn::sinkhorn::sinkhorn_divergence;
use linear_sinkhorn::testing::property;

/// f64 reference `a^T v` for error bounds.
fn matvec_t_ref64(a: &Mat, v: &[f32]) -> Vec<f64> {
    let (n, k) = a.shape();
    let mut out = vec![0.0f64; k];
    for i in 0..n {
        let vi = v[i] as f64;
        for (o, &x) in out.iter_mut().zip(a.row(i)) {
            *o += x as f64 * vi;
        }
    }
    out
}

#[test]
fn property_matvec_pooled_is_bitwise_serial() {
    property("matvec_pooled_bitwise", 12, |g| {
        let n = g.usize_in(1, 1400);
        let k = g.usize_in(1, 130);
        let a = g.cloud(n, k, 1.5);
        let v: Vec<f32> = (0..k).map(|_| g.rng.normal_f32()).collect();
        let mut serial = vec![0.0f32; n];
        matvec_into(&a, &v, &mut serial);
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut pooled = vec![0.0f32; n];
            matvec_into_pooled(&a, &v, &mut pooled, &pool);
            for i in 0..n {
                assert_eq!(
                    serial[i].to_bits(),
                    pooled[i].to_bits(),
                    "row {i} differs at threads={threads}"
                );
            }
        }
    });
}

#[test]
fn property_matvec_t_pooled_thread_invariant_and_accurate() {
    property("matvec_t_pooled", 12, |g| {
        let n = g.usize_in(1, 5000);
        let k = g.usize_in(1, 80);
        // Positive entries — the Sinkhorn regime (factors and scalings are
        // strictly positive), where summation reorders cannot cancel.
        let a = g.positive_mat(n, k, 0.05, 2.0);
        let v: Vec<f32> = (0..n).map(|_| g.f64_in(0.05, 2.0) as f32).collect();

        let mut serial = vec![0.0f32; k];
        matvec_t_into(&a, &v, &mut serial);
        let reference = matvec_t_ref64(&a, &v);

        let mut first: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut pooled = vec![0.0f32; k];
            matvec_t_into_pooled(&a, &v, &mut pooled, &pool);
            match &first {
                None => first = Some(pooled.clone()),
                Some(f) => {
                    for j in 0..k {
                        assert_eq!(
                            f[j].to_bits(),
                            pooled[j].to_bits(),
                            "col {j}: thread count changed the result"
                        );
                    }
                }
            }
            for j in 0..k {
                let rel = ((pooled[j] as f64) - reference[j]).abs() / reference[j].abs().max(1e-30);
                assert!(rel <= 1e-5, "col {j}: pooled off reference by {rel:.2e}");
                let rel_s =
                    ((serial[j] as f64) - (pooled[j] as f64)).abs() / reference[j].abs().max(1e-30);
                assert!(rel_s <= 1e-5, "col {j}: pooled vs serial {rel_s:.2e}");
            }
        }
    });
}

/// f64 reference for `out_j = logsumexp_i(alpha a[i,j] + u_i)`.
fn lse_matvec_t_ref(a: &Mat, alpha: f64, u: &[f64]) -> Vec<f64> {
    let (n, k) = a.shape();
    (0..k)
        .map(|j| {
            let terms: Vec<f64> =
                (0..n).map(|i| alpha * a[(i, j)] as f64 + u[i]).collect();
            let m = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if !m.is_finite() {
                return m;
            }
            m + terms.iter().map(|&v| (v - m).exp()).sum::<f64>().ln()
        })
        .collect()
}

#[test]
fn property_lse_matvec_pooled_is_bitwise_serial() {
    property("lse_matvec_pooled_bitwise", 10, |g| {
        let n = g.usize_in(1, 1200);
        let k = g.usize_in(1, 64);
        let a = g.cloud(n, k, 2.0);
        // Log-scale inputs spanning the magnitudes the log-domain solver
        // feeds (duals/eps at small eps).
        let t: Vec<f64> = (0..k).map(|_| g.f64_in(-2e3, 10.0)).collect();
        let alpha = g.f64_in(-3.0, 3.0);
        let mut serial = vec![0.0f64; n];
        lse_matvec_into(&a, alpha, &t, &mut serial);
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut pooled = vec![0.0f64; n];
            lse_matvec_into_pooled(&a, alpha, &t, &mut pooled, &pool);
            for i in 0..n {
                assert_eq!(
                    serial[i].to_bits(),
                    pooled[i].to_bits(),
                    "row {i} differs at threads={threads}"
                );
            }
        }
    });
}

#[test]
fn property_lse_matvec_t_pooled_thread_invariant_and_accurate() {
    property("lse_matvec_t_pooled", 10, |g| {
        // Cross the 1024-row chunk grid so the chunked merge really runs.
        let n = g.usize_in(1, 4000);
        let k = g.usize_in(1, 48);
        let a = g.cloud(n, k, 2.0);
        let u: Vec<f64> = (0..n).map(|_| g.f64_in(-2e3, 10.0)).collect();
        let alpha = g.f64_in(-3.0, 3.0);
        let reference = lse_matvec_t_ref(&a, alpha, &u);

        let mut serial = vec![0.0f64; k];
        lse_matvec_t_into(&a, alpha, &u, &mut serial);

        let mut first: Option<Vec<f64>> = None;
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut pooled = vec![0.0f64; k];
            lse_matvec_t_into_pooled(&a, alpha, &u, &mut pooled, &pool);
            match &first {
                None => first = Some(pooled.clone()),
                Some(f) => {
                    for j in 0..k {
                        assert_eq!(
                            f[j].to_bits(),
                            pooled[j].to_bits(),
                            "col {j}: thread count changed the result"
                        );
                    }
                }
            }
            for j in 0..k {
                let scale = reference[j].abs().max(1.0);
                let rel = (pooled[j] - reference[j]).abs() / scale;
                assert!(rel <= 1e-10, "col {j}: pooled off reference by {rel:.2e}");
                let rel_s = (serial[j] - pooled[j]).abs() / scale;
                assert!(rel_s <= 1e-10, "col {j}: pooled vs serial {rel_s:.2e}");
            }
        }
    });
}

#[test]
fn property_parallel_feature_matrices_bitwise_serial() {
    property("par_features", 6, |g| {
        let n = g.usize_in(1, 300);
        let r = g.usize_in(1, 96);
        let eps = g.f64_in(0.2, 2.0);
        let pts = g.cloud(n, 2, 1.0);
        let map = GaussianFeatureMap::new(eps, 3.0, 2, r, &mut g.rng);
        let serial = map.feature_matrix(&pts);
        let serial_log = map.log_feature_matrix(&pts);
        for threads in [2usize, 4] {
            let pool = Pool::new(threads);
            let par = par_feature_matrix(&map, &pts, &pool);
            let par_log = par_log_feature_matrix(&map, &pts, &pool);
            assert_eq!(serial.data(), par.data(), "feature rows are independent");
            assert_eq!(serial_log.data(), par_log.data(), "log-feature rows are independent");
        }
    });
}

#[test]
fn divergence_identical_with_1_and_n_threads() {
    // Full-stack determinism at a size that actually exercises chunked
    // matvecs (n > one transpose chunk of 1024 rows).
    let mut rng = Rng::seed_from(42);
    let n = 1500;
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    let eps = 0.5;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 64, &mut rng);

    let run = |threads: usize| -> f64 {
        let pool = Pool::new(threads);
        let k_xy = FactoredKernel::from_measures_pooled(&map, &mu, &nu, pool.clone());
        let k_xx = FactoredKernel::from_measures_pooled(&map, &mu, &mu, pool.clone());
        let k_yy = FactoredKernel::from_measures_pooled(&map, &nu, &nu, pool);
        let cfg = SinkhornConfig {
            epsilon: eps,
            max_iters: 40,
            tol: 1e-5,
            check_every: 10,
            threads,
            stabilize: false,
            max_batch: 1,
            anneal: None,
            anneal_decay: 0.5,
            symmetric: None,
        };
        sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg).unwrap()
    };

    let d1 = run(1);
    for threads in [2usize, 4] {
        let dn = run(threads);
        assert_eq!(d1.to_bits(), dn.to_bits(), "threads={threads}: {d1} vs {dn}");
    }
}

/// Sizes that straddle the SIMD lane boundaries (8/16-lane f32, 4-lane
/// f64), the 64-element `row_dot` block, and the fixed pool chunk grids
/// (256/1024 rows) — none of the interesting ones are lane multiples.
const LANE_BOUNDARY_SIZES: [usize; 14] = [0, 1, 3, 7, 8, 9, 15, 16, 17, 63, 65, 127, 129, 1025];

/// The two dispatch arms under test. On machines without AVX2+FMA the
/// second entry sanitises to scalar and the comparisons are trivially
/// exact — the CI x86_64 legs exercise the real pair.
fn arms() -> [SimdLevel; 2] {
    [SimdLevel::Scalar, SimdLevel::Avx2Fma.sanitize()]
}

#[test]
fn simd_arms_agree_on_lane_boundary_matvecs() {
    // Scalar-vs-SIMD agreement within the documented tolerances: 1e-5
    // relative for the f32 kernels (FMA + wider lanes re-associate the
    // f32 partials; the f64 block accumulation bounds the drift), against
    // an f64 reference so neither arm is privileged.
    let mut rng = Rng::seed_from(91);
    for &n in &LANE_BOUNDARY_SIZES {
        for &k in &[1usize, 7, 8, 9, 64, 65] {
            let a = Mat::from_fn(n, k, |_, _| rng.uniform_in(0.05, 2.0) as f32);
            let v: Vec<f32> = (0..k).map(|_| rng.uniform_in(0.05, 2.0) as f32).collect();
            let u: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.05, 2.0) as f32).collect();

            let mut out_s = vec![0.0f32; n];
            matvec_into_at(SimdLevel::Scalar, &a, &v, &mut out_s);
            let mut out_v = vec![0.0f32; n];
            matvec_into_at(SimdLevel::Avx2Fma.sanitize(), &a, &v, &mut out_v);
            for i in 0..n {
                let reference: f64 =
                    (0..k).map(|j| (a[(i, j)] as f64) * (v[j] as f64)).sum();
                let scale = reference.abs().max(1.0);
                assert!(
                    ((out_s[i] as f64) - (out_v[i] as f64)).abs() / scale <= 1e-5,
                    "matvec ({n},{k}) row {i}: {} vs {}",
                    out_s[i],
                    out_v[i]
                );
            }

            let mut t_s = vec![0.0f32; k];
            matvec_t_into_at(SimdLevel::Scalar, &a, &u, &mut t_s);
            let mut t_v = vec![0.0f32; k];
            matvec_t_into_at(SimdLevel::Avx2Fma.sanitize(), &a, &u, &mut t_v);
            let reference = matvec_t_ref64(&a, &u);
            for j in 0..k {
                let scale = reference[j].abs().max(1.0);
                assert!(
                    ((t_s[j] as f64) - (t_v[j] as f64)).abs() / scale <= 1e-5,
                    "matvec_t ({n},{k}) col {j}: {} vs {}",
                    t_s[j],
                    t_v[j]
                );
            }
        }
    }
}

#[test]
fn simd_arms_agree_on_lane_boundary_lse() {
    // The f64 logsumexp kernels: the AVX2 arm's vexp carries a ≤ 2 ulp
    // contract and the lane reductions re-associate the f64 sum, so the
    // arms agree to ~1e-12 relative — far inside the 1e-10 bound the
    // pooled lse tests already assert against an f64 reference.
    let mut rng = Rng::seed_from(92);
    for &n in &LANE_BOUNDARY_SIZES {
        for &k in &[1usize, 3, 4, 5, 9, 33] {
            let a = Mat::from_fn(n, k, |_, _| rng.normal_f32() * 2.0);
            let t: Vec<f64> = (0..k).map(|_| rng.uniform_in(-100.0, 10.0)).collect();
            let u: Vec<f64> = (0..n).map(|_| rng.uniform_in(-100.0, 10.0)).collect();
            let alpha = -1.3;

            let mut r_s = vec![0.0f64; n];
            lse_matvec_into_at(SimdLevel::Scalar, &a, alpha, &t, &mut r_s);
            let mut r_v = vec![0.0f64; n];
            lse_matvec_into_at(SimdLevel::Avx2Fma.sanitize(), &a, alpha, &t, &mut r_v);
            for i in 0..n {
                let scale = r_s[i].abs().max(1.0);
                assert!(
                    (r_s[i] - r_v[i]).abs() / scale <= 1e-12,
                    "lse_matvec ({n},{k}) row {i}: {} vs {}",
                    r_s[i],
                    r_v[i]
                );
            }

            let mut c_s = vec![0.0f64; k];
            lse_matvec_t_into_at(SimdLevel::Scalar, &a, alpha, &u, &mut c_s);
            let mut c_v = vec![0.0f64; k];
            lse_matvec_t_into_at(SimdLevel::Avx2Fma.sanitize(), &a, alpha, &u, &mut c_v);
            for j in 0..k {
                if n == 0 {
                    // Empty reduction: both arms report -inf columns.
                    assert_eq!(c_s[j], f64::NEG_INFINITY);
                    assert_eq!(c_v[j], f64::NEG_INFINITY);
                    continue;
                }
                let scale = c_s[j].abs().max(1.0);
                assert!(
                    (c_s[j] - c_v[j]).abs() / scale <= 1e-12,
                    "lse_matvec_t ({n},{k}) col {j}: {} vs {}",
                    c_s[j],
                    c_v[j]
                );
            }
        }
    }
}

#[test]
fn simd_arms_pooled_bitwise_one_vs_n_threads_per_arm() {
    // The thread-count-determinism invariant, pinned per dispatch arm:
    // on either arm, every pool size reproduces the serial kernel's bits
    // (plain matvec / lse rows) or a fixed chunk-grid reduction of them
    // (transposed kernels). Sizes cross the 256/1024-row chunk grids and
    // avoid lane multiples.
    let mut rng = Rng::seed_from(93);
    for level in arms() {
        for &(n, k) in &[(519usize, 67usize), (1025, 33), (2300, 13)] {
            let a = Mat::from_fn(n, k, |_, _| rng.uniform_in(0.05, 2.0) as f32);
            let v: Vec<f32> = (0..k).map(|_| rng.uniform_in(0.05, 2.0) as f32).collect();
            let u: Vec<f32> = (0..n).map(|_| rng.uniform_in(0.05, 2.0) as f32).collect();
            let t: Vec<f64> = (0..k).map(|_| rng.uniform_in(-50.0, 5.0)).collect();
            let w: Vec<f64> = (0..n).map(|_| rng.uniform_in(-50.0, 5.0)).collect();

            let mut mv1 = vec![0.0f32; n];
            matvec_into_at(level, &a, &v, &mut mv1);
            let mut mt_first: Option<Vec<f32>> = None;
            let mut lt_first: Option<Vec<f64>> = None;
            for threads in [1usize, 2, 4] {
                let pool = Pool::new(threads);

                let mut mv = vec![0.0f32; n];
                matvec_into_pooled_at(level, &a, &v, &mut mv, &pool);
                assert!(
                    mv1.iter().zip(&mv).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} matvec n={n} threads={threads}",
                    level.label()
                );

                let mut lr1 = vec![0.0f64; n];
                lse_matvec_into_at(level, &a, -0.7, &t, &mut lr1);
                let mut lr = vec![0.0f64; n];
                lse_matvec_into_pooled_at(level, &a, -0.7, &t, &mut lr, &pool);
                assert!(
                    lr1.iter().zip(&lr).all(|(x, y)| x.to_bits() == y.to_bits()),
                    "{} lse_matvec n={n} threads={threads}",
                    level.label()
                );

                let mut mt = vec![0.0f32; k];
                matvec_t_into_pooled_at(level, &a, &u, &mut mt, &pool);
                match &mt_first {
                    None => mt_first = Some(mt),
                    Some(f) => assert!(
                        f.iter().zip(&mt).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "{} matvec_t n={n} threads={threads}",
                        level.label()
                    ),
                }

                let mut lt = vec![0.0f64; k];
                lse_matvec_t_into_pooled_at(level, &a, -0.7, &w, &mut lt, &pool);
                match &lt_first {
                    None => lt_first = Some(lt),
                    Some(f) => assert!(
                        f.iter().zip(&lt).all(|(x, y)| x.to_bits() == y.to_bits()),
                        "{} lse_matvec_t n={n} threads={threads}",
                        level.label()
                    ),
                }
            }
        }
    }
}

#[test]
fn simd_dispatched_default_matches_active_level_arm() {
    // The level-less public kernels are exactly the `_at` kernels pinned
    // to `active_level()` — dispatch adds no third behaviour.
    let mut rng = Rng::seed_from(94);
    let a = Mat::from_fn(130, 67, |_, _| rng.normal_f32());
    let v: Vec<f32> = (0..67).map(|_| rng.normal_f32()).collect();
    let mut via_default = vec![0.0f32; 130];
    matvec_into(&a, &v, &mut via_default);
    let mut via_at = vec![0.0f32; 130];
    matvec_into_at(active_level(), &a, &v, &mut via_at);
    assert!(via_default.iter().zip(&via_at).all(|(x, y)| x.to_bits() == y.to_bits()));
}

/// The pre-pool factored kernel: applies through the plain serial
/// `matvec_t_into`/`matvec_into` only — never the chunked reduction —
/// reproducing the historical code path for any n.
struct LegacyFactored {
    phi_x: Mat,
    phi_y: Mat,
    scratch: std::sync::Mutex<Vec<f32>>,
}

impl LegacyFactored {
    fn new(phi_x: Mat, phi_y: Mat) -> Self {
        let r = phi_x.cols();
        LegacyFactored { phi_x, phi_y, scratch: std::sync::Mutex::new(vec![0.0; r]) }
    }
}

impl KernelOp for LegacyFactored {
    fn rows(&self) -> usize {
        self.phi_x.rows()
    }
    fn cols(&self) -> usize {
        self.phi_y.rows()
    }
    fn apply_into(&self, v: &[f32], out: &mut [f32]) {
        let mut t = self.scratch.lock().unwrap();
        matvec_t_into(&self.phi_y, v, &mut t);
        matvec_into(&self.phi_x, &t, out);
    }
    fn apply_t_into(&self, u: &[f32], out: &mut [f32]) {
        let mut t = self.scratch.lock().unwrap();
        matvec_t_into(&self.phi_x, u, &mut t);
        matvec_into(&self.phi_y, &t, out);
    }
    fn min_entry(&self) -> f64 {
        1e-30 // unused by Alg. 1
    }
    fn flops_per_apply(&self) -> u64 {
        0 // unused by Alg. 1
    }
    fn label(&self) -> String {
        "legacy-RF".into()
    }
}

#[test]
fn divergence_agrees_with_historical_serial_path() {
    // The pooled kernels re-associate the transpose reduction for
    // n > 1024; the objective must still match the true pre-pool code
    // path (plain serial matvec_t) tightly. n = 1200 forces the chunked
    // reduction in the pooled arm while LegacyFactored never takes it.
    let mut rng = Rng::seed_from(7);
    let (mu, nu) = data::gaussian_blobs(1200, &mut rng);
    let eps = 0.5;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 64, &mut rng);
    let cfg = SinkhornConfig {
        epsilon: eps,
        max_iters: 60,
        tol: 1e-5,
        check_every: 10,
        threads: 1,
        stabilize: false,
        max_batch: 1,
        anneal: None,
        anneal_decay: 0.5,
        symmetric: None,
    };

    let phi_mu = map.feature_matrix(&mu.points);
    let phi_nu = map.feature_matrix(&nu.points);
    let legacy = {
        let k_xy = LegacyFactored::new(phi_mu.clone(), phi_nu.clone());
        let k_xx = LegacyFactored::new(phi_mu.clone(), phi_mu.clone());
        let k_yy = LegacyFactored::new(phi_nu.clone(), phi_nu.clone());
        sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg).unwrap()
    };
    let pooled = {
        let pool = Pool::new(4);
        let k_xy = FactoredKernel::from_measures_pooled(&map, &mu, &nu, pool.clone());
        let k_xx = FactoredKernel::from_measures_pooled(&map, &mu, &mu, pool.clone());
        let k_yy = FactoredKernel::from_measures_pooled(&map, &nu, &nu, pool);
        let cfg = SinkhornConfig { threads: 4, ..cfg };
        sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg).unwrap()
    };
    let denom = legacy.abs().max(1e-9);
    assert!(
        (legacy - pooled).abs() / denom < 1e-4,
        "legacy {legacy} vs pooled {pooled}"
    );
}
