//! Parallel-vs-serial equivalence: the intra-solve execution layer
//! (`runtime::pool` + the `_pooled` linalg kernels + the concurrent
//! three-problem divergence) must change wall-clock only, never numbers.
//!
//! Four layers of guarantee are asserted here:
//! 1. `matvec_into_pooled` is **bitwise** equal to `matvec_into` (rows are
//!    independent and share the per-row kernel).
//! 2. `matvec_t_into_pooled` is **thread-count invariant** (fixed chunk
//!    grid, ordered f64 reduce) and agrees with the serial kernel and an
//!    f64 reference to well under 1e-5 relative even at n = 5000 — the
//!    reorder only moves f32 rounding, it cannot cancel on the positive
//!    data Sinkhorn feeds it.
//! 3. The pooled logsumexp primitives (`lse_matvec_into_pooled`,
//!    `lse_matvec_t_into_pooled`) behind the log-domain solver obey the
//!    same contract: bitwise thread-count invariance on a fixed chunk
//!    grid, and near-f64-reference accuracy through the chunked merge.
//! 4. `sinkhorn_divergence` returns bit-identical objectives with 1 and N
//!    threads, at both the solve level (`cfg.threads`) and the matvec
//!    level (kernel pools).

use linear_sinkhorn::config::SinkhornConfig;
use linear_sinkhorn::features::{par_feature_matrix, par_log_feature_matrix};
use linear_sinkhorn::linalg::{
    lse_matvec_into, lse_matvec_into_pooled, lse_matvec_t_into, lse_matvec_t_into_pooled,
    matvec_into, matvec_into_pooled, matvec_t_into, matvec_t_into_pooled, Mat,
};
use linear_sinkhorn::prelude::*;
use linear_sinkhorn::testing::property;

/// f64 reference `a^T v` for error bounds.
fn matvec_t_ref64(a: &Mat, v: &[f32]) -> Vec<f64> {
    let (n, k) = a.shape();
    let mut out = vec![0.0f64; k];
    for i in 0..n {
        let vi = v[i] as f64;
        for (o, &x) in out.iter_mut().zip(a.row(i)) {
            *o += x as f64 * vi;
        }
    }
    out
}

#[test]
fn property_matvec_pooled_is_bitwise_serial() {
    property("matvec_pooled_bitwise", 12, |g| {
        let n = g.usize_in(1, 1400);
        let k = g.usize_in(1, 130);
        let a = g.cloud(n, k, 1.5);
        let v: Vec<f32> = (0..k).map(|_| g.rng.normal_f32()).collect();
        let mut serial = vec![0.0f32; n];
        matvec_into(&a, &v, &mut serial);
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut pooled = vec![0.0f32; n];
            matvec_into_pooled(&a, &v, &mut pooled, &pool);
            for i in 0..n {
                assert_eq!(
                    serial[i].to_bits(),
                    pooled[i].to_bits(),
                    "row {i} differs at threads={threads}"
                );
            }
        }
    });
}

#[test]
fn property_matvec_t_pooled_thread_invariant_and_accurate() {
    property("matvec_t_pooled", 12, |g| {
        let n = g.usize_in(1, 5000);
        let k = g.usize_in(1, 80);
        // Positive entries — the Sinkhorn regime (factors and scalings are
        // strictly positive), where summation reorders cannot cancel.
        let a = g.positive_mat(n, k, 0.05, 2.0);
        let v: Vec<f32> = (0..n).map(|_| g.f64_in(0.05, 2.0) as f32).collect();

        let mut serial = vec![0.0f32; k];
        matvec_t_into(&a, &v, &mut serial);
        let reference = matvec_t_ref64(&a, &v);

        let mut first: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut pooled = vec![0.0f32; k];
            matvec_t_into_pooled(&a, &v, &mut pooled, &pool);
            match &first {
                None => first = Some(pooled.clone()),
                Some(f) => {
                    for j in 0..k {
                        assert_eq!(
                            f[j].to_bits(),
                            pooled[j].to_bits(),
                            "col {j}: thread count changed the result"
                        );
                    }
                }
            }
            for j in 0..k {
                let rel = ((pooled[j] as f64) - reference[j]).abs() / reference[j].abs().max(1e-30);
                assert!(rel <= 1e-5, "col {j}: pooled off reference by {rel:.2e}");
                let rel_s =
                    ((serial[j] as f64) - (pooled[j] as f64)).abs() / reference[j].abs().max(1e-30);
                assert!(rel_s <= 1e-5, "col {j}: pooled vs serial {rel_s:.2e}");
            }
        }
    });
}

/// f64 reference for `out_j = logsumexp_i(alpha a[i,j] + u_i)`.
fn lse_matvec_t_ref(a: &Mat, alpha: f64, u: &[f64]) -> Vec<f64> {
    let (n, k) = a.shape();
    (0..k)
        .map(|j| {
            let terms: Vec<f64> =
                (0..n).map(|i| alpha * a[(i, j)] as f64 + u[i]).collect();
            let m = terms.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            if !m.is_finite() {
                return m;
            }
            m + terms.iter().map(|&v| (v - m).exp()).sum::<f64>().ln()
        })
        .collect()
}

#[test]
fn property_lse_matvec_pooled_is_bitwise_serial() {
    property("lse_matvec_pooled_bitwise", 10, |g| {
        let n = g.usize_in(1, 1200);
        let k = g.usize_in(1, 64);
        let a = g.cloud(n, k, 2.0);
        // Log-scale inputs spanning the magnitudes the log-domain solver
        // feeds (duals/eps at small eps).
        let t: Vec<f64> = (0..k).map(|_| g.f64_in(-2e3, 10.0)).collect();
        let alpha = g.f64_in(-3.0, 3.0);
        let mut serial = vec![0.0f64; n];
        lse_matvec_into(&a, alpha, &t, &mut serial);
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut pooled = vec![0.0f64; n];
            lse_matvec_into_pooled(&a, alpha, &t, &mut pooled, &pool);
            for i in 0..n {
                assert_eq!(
                    serial[i].to_bits(),
                    pooled[i].to_bits(),
                    "row {i} differs at threads={threads}"
                );
            }
        }
    });
}

#[test]
fn property_lse_matvec_t_pooled_thread_invariant_and_accurate() {
    property("lse_matvec_t_pooled", 10, |g| {
        // Cross the 1024-row chunk grid so the chunked merge really runs.
        let n = g.usize_in(1, 4000);
        let k = g.usize_in(1, 48);
        let a = g.cloud(n, k, 2.0);
        let u: Vec<f64> = (0..n).map(|_| g.f64_in(-2e3, 10.0)).collect();
        let alpha = g.f64_in(-3.0, 3.0);
        let reference = lse_matvec_t_ref(&a, alpha, &u);

        let mut serial = vec![0.0f64; k];
        lse_matvec_t_into(&a, alpha, &u, &mut serial);

        let mut first: Option<Vec<f64>> = None;
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut pooled = vec![0.0f64; k];
            lse_matvec_t_into_pooled(&a, alpha, &u, &mut pooled, &pool);
            match &first {
                None => first = Some(pooled.clone()),
                Some(f) => {
                    for j in 0..k {
                        assert_eq!(
                            f[j].to_bits(),
                            pooled[j].to_bits(),
                            "col {j}: thread count changed the result"
                        );
                    }
                }
            }
            for j in 0..k {
                let scale = reference[j].abs().max(1.0);
                let rel = (pooled[j] - reference[j]).abs() / scale;
                assert!(rel <= 1e-10, "col {j}: pooled off reference by {rel:.2e}");
                let rel_s = (serial[j] - pooled[j]).abs() / scale;
                assert!(rel_s <= 1e-10, "col {j}: pooled vs serial {rel_s:.2e}");
            }
        }
    });
}

#[test]
fn property_parallel_feature_matrices_bitwise_serial() {
    property("par_features", 6, |g| {
        let n = g.usize_in(1, 300);
        let r = g.usize_in(1, 96);
        let eps = g.f64_in(0.2, 2.0);
        let pts = g.cloud(n, 2, 1.0);
        let map = GaussianFeatureMap::new(eps, 3.0, 2, r, &mut g.rng);
        let serial = map.feature_matrix(&pts);
        let serial_log = map.log_feature_matrix(&pts);
        for threads in [2usize, 4] {
            let pool = Pool::new(threads);
            let par = par_feature_matrix(&map, &pts, &pool);
            let par_log = par_log_feature_matrix(&map, &pts, &pool);
            assert_eq!(serial.data(), par.data(), "feature rows are independent");
            assert_eq!(serial_log.data(), par_log.data(), "log-feature rows are independent");
        }
    });
}

#[test]
fn divergence_identical_with_1_and_n_threads() {
    // Full-stack determinism at a size that actually exercises chunked
    // matvecs (n > one transpose chunk of 1024 rows).
    let mut rng = Rng::seed_from(42);
    let n = 1500;
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    let eps = 0.5;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 64, &mut rng);

    let run = |threads: usize| -> f64 {
        let pool = Pool::new(threads);
        let k_xy = FactoredKernel::from_measures_pooled(&map, &mu, &nu, pool.clone());
        let k_xx = FactoredKernel::from_measures_pooled(&map, &mu, &mu, pool.clone());
        let k_yy = FactoredKernel::from_measures_pooled(&map, &nu, &nu, pool);
        let cfg = SinkhornConfig {
            epsilon: eps,
            max_iters: 40,
            tol: 1e-5,
            check_every: 10,
            threads,
            stabilize: false,
            max_batch: 1,
        };
        sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg).unwrap()
    };

    let d1 = run(1);
    for threads in [2usize, 4] {
        let dn = run(threads);
        assert_eq!(d1.to_bits(), dn.to_bits(), "threads={threads}: {d1} vs {dn}");
    }
}

/// The pre-pool factored kernel: applies through the plain serial
/// `matvec_t_into`/`matvec_into` only — never the chunked reduction —
/// reproducing the historical code path for any n.
struct LegacyFactored {
    phi_x: Mat,
    phi_y: Mat,
    scratch: std::sync::Mutex<Vec<f32>>,
}

impl LegacyFactored {
    fn new(phi_x: Mat, phi_y: Mat) -> Self {
        let r = phi_x.cols();
        LegacyFactored { phi_x, phi_y, scratch: std::sync::Mutex::new(vec![0.0; r]) }
    }
}

impl KernelOp for LegacyFactored {
    fn rows(&self) -> usize {
        self.phi_x.rows()
    }
    fn cols(&self) -> usize {
        self.phi_y.rows()
    }
    fn apply_into(&self, v: &[f32], out: &mut [f32]) {
        let mut t = self.scratch.lock().unwrap();
        matvec_t_into(&self.phi_y, v, &mut t);
        matvec_into(&self.phi_x, &t, out);
    }
    fn apply_t_into(&self, u: &[f32], out: &mut [f32]) {
        let mut t = self.scratch.lock().unwrap();
        matvec_t_into(&self.phi_x, u, &mut t);
        matvec_into(&self.phi_y, &t, out);
    }
    fn min_entry(&self) -> f64 {
        1e-30 // unused by Alg. 1
    }
    fn flops_per_apply(&self) -> u64 {
        0 // unused by Alg. 1
    }
    fn label(&self) -> String {
        "legacy-RF".into()
    }
}

#[test]
fn divergence_agrees_with_historical_serial_path() {
    // The pooled kernels re-associate the transpose reduction for
    // n > 1024; the objective must still match the true pre-pool code
    // path (plain serial matvec_t) tightly. n = 1200 forces the chunked
    // reduction in the pooled arm while LegacyFactored never takes it.
    let mut rng = Rng::seed_from(7);
    let (mu, nu) = data::gaussian_blobs(1200, &mut rng);
    let eps = 0.5;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, 64, &mut rng);
    let cfg = SinkhornConfig {
        epsilon: eps,
        max_iters: 60,
        tol: 1e-5,
        check_every: 10,
        threads: 1,
        stabilize: false,
        max_batch: 1,
    };

    let phi_mu = map.feature_matrix(&mu.points);
    let phi_nu = map.feature_matrix(&nu.points);
    let legacy = {
        let k_xy = LegacyFactored::new(phi_mu.clone(), phi_nu.clone());
        let k_xx = LegacyFactored::new(phi_mu.clone(), phi_mu.clone());
        let k_yy = LegacyFactored::new(phi_nu.clone(), phi_nu.clone());
        sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg).unwrap()
    };
    let pooled = {
        let pool = Pool::new(4);
        let k_xy = FactoredKernel::from_measures_pooled(&map, &mu, &nu, pool.clone());
        let k_xx = FactoredKernel::from_measures_pooled(&map, &mu, &mu, pool.clone());
        let k_yy = FactoredKernel::from_measures_pooled(&map, &nu, &nu, pool);
        let cfg = SinkhornConfig { threads: 4, ..cfg };
        sinkhorn_divergence(&k_xy, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg).unwrap()
    };
    let denom = legacy.abs().max(1e-9);
    assert!(
        (legacy - pooled).abs() / denom < 1e-4,
        "legacy {legacy} vs pooled {pooled}"
    );
}
