//! Serving example: start the L3 coordinator (router + dynamic batcher +
//! worker pool) and drive it with a mixed workload from multiple client
//! threads, reporting throughput, latency quantiles and shed counts —
//! then run the same requests through the PJRT runtime path (AOT-compiled
//! HLO divergence graph) when artifacts are available.
//!
//! Run with: `cargo run --release --example divergence_service`

use std::sync::Arc;

use linear_sinkhorn::config::{BatcherConfig, ServiceConfig, SinkhornConfig};
use linear_sinkhorn::coordinator::Service;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;
use linear_sinkhorn::runtime::{mat_to_literal, vec_to_literal, Engine, Registry};

fn main() {
    let cfg = ServiceConfig {
        workers: 4,
        batcher: BatcherConfig { max_batch: 8, max_delay_us: 300, queue_depth: 256 },
        sinkhorn: SinkhornConfig {
            epsilon: 0.5,
            max_iters: 1000,
            tol: 1e-4,
            check_every: 10,
            ..Default::default()
        },
        num_features: 256,
        solver_threads: 1,
        cache_capacity: 8,
        ..Default::default()
    };
    println!(
        "starting divergence service: {} workers, batch<= {}, queue {}",
        cfg.workers, cfg.batcher.max_batch, cfg.batcher.queue_depth
    );
    let svc = Service::start(cfg).expect("service start");
    let handle = svc.handle();

    // Three client threads with different workload mixes.
    let sw = Stopwatch::start();
    let clients: Vec<std::thread::JoinHandle<(usize, usize)>> = (0..3)
        .map(|c| {
            let h = handle.clone();
            std::thread::spawn(move || {
                let mut rng = Rng::seed_from(c as u64 + 100);
                let mut done = 0;
                let mut shed = 0;
                for i in 0..20 {
                    let n = [200, 400, 800][(c as usize + i) % 3];
                    // High-dimensional clouds need a larger regularisation
                    // (squared distances scale with d) — use the
                    // per-request epsilon override for the Higgs client.
                    let (mu, nu, eps) = if c == 0 {
                        let (a, b) = data::gaussian_blobs(n, &mut rng);
                        (a, b, None)
                    } else if c == 1 {
                        let (a, b) = data::sphere_caps(n, &mut rng);
                        (a, b, None)
                    } else {
                        let (a, b) = data::higgs_pair(n, &mut rng);
                        (a, b, Some(10.0))
                    };
                    match h.submit_with(mu, nu, eps) {
                        Ok(p) => match p.wait() {
                            Ok(resp) => {
                                done += 1;
                                if done == 1 {
                                    println!(
                                        "client {c}: first response divergence={:.5} \
                                         latency={}us batch={}",
                                        resp.divergence, resp.latency_us, resp.batch_size
                                    );
                                }
                            }
                            Err(e) => println!("client {c}: solve error {e}"),
                        },
                        Err(_) => shed += 1,
                    }
                }
                (done, shed)
            })
        })
        .collect();

    let mut total = 0;
    let mut shed = 0;
    for c in clients {
        let (d, s) = c.join().unwrap();
        total += d;
        shed += s;
    }
    let secs = sw.elapsed_secs();
    println!(
        "\nserved {total} requests ({shed} shed) in {secs:.2}s = {:.1} req/s",
        total as f64 / secs
    );
    println!("{}", handle.metrics_text());
    drop(handle);
    svc.shutdown();

    // PJRT runtime path: run the AOT divergence graph if artifacts exist.
    match Registry::load("artifacts") {
        Ok(reg) => match reg.find_prefix("rf_divergence_n256") {
            Some(meta) => {
                println!("PJRT path: compiling {} …", meta.name);
                let engine = Arc::new(Engine::cpu().expect("pjrt cpu client"));
                let exe = engine.load(meta).expect("compile artifact");
                // Shapes from the manifest: x, y (n, d), anchors (r, d), a, b (n).
                let n = meta.params[0].1[0];
                let d = meta.params[0].1[1];
                let r = meta.params[2].1[0];
                let q = meta.constants["q"];
                let eps = meta.constants["eps"];
                let mut rng = Rng::seed_from(7);
                let (mu, nu) = data::gaussian_blobs(n, &mut rng);
                let sigma = (q * eps / 4.0).sqrt();
                let anchors =
                    Mat::from_fn(r, d, |_, _| rng.normal_scaled(0.0, sigma) as f32);
                let sw = Stopwatch::start();
                let out = exe
                    .run(&[
                        mat_to_literal(&mu.points).unwrap(),
                        mat_to_literal(&nu.points).unwrap(),
                        mat_to_literal(&anchors).unwrap(),
                        vec_to_literal(&mu.weights),
                        vec_to_literal(&nu.weights),
                    ])
                    .expect("execute");
                let div = out[0].to_vec::<f32>().unwrap()[0];
                println!(
                    "PJRT divergence (n={n}, r={r}, eps={eps}): {div:.6} in {:.1} ms \
                     (python never ran)",
                    sw.elapsed_secs() * 1e3
                );
            }
            None => println!("no rf_divergence artifact in manifest; skipping PJRT demo"),
        },
        Err(e) => {
            println!("artifacts not built ({e}); skipping PJRT demo — run `make artifacts`")
        }
    }
}
