//! Fig-6 example: Wasserstein barycenters on the positive sphere with the
//! cost `c(x, y) = -log x^T y` (Remark 1), whose kernel is *exactly* the
//! rank-3 factored kernel `K = X X^T` — no approximation at all.
//!
//! Renders the three corner histograms, the IBP barycenter and its
//! temperature-1000 softmax sharpening as coarse ASCII heatmaps.
//!
//! Run with: `cargo run --release --example sphere_barycenter`

use linear_sinkhorn::barycenter::{barycenter, BarycenterConfig};
use linear_sinkhorn::features::{FeatureMap, SphereLinearMap};
use linear_sinkhorn::linalg::softmax_inplace;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

/// Print a side x side histogram as an ASCII heatmap.
fn heatmap(title: &str, h: &[f32], side: usize) {
    const RAMP: &[u8] = b" .:-=+*#%@";
    let max = h.iter().cloned().fold(f32::MIN, f32::max).max(1e-20);
    println!("{title}:");
    // Downsample to at most 25 rows for terminal friendliness.
    let step = (side / 25).max(1);
    for i in (0..side).step_by(step) {
        let mut line = String::with_capacity(side / step + 2);
        for j in (0..side).step_by(step) {
            // Max-pool the cell block.
            let mut m = 0.0f32;
            for di in 0..step.min(side - i) {
                for dj in 0..step.min(side - j) {
                    m = m.max(h[(i + di) * side + (j + dj)]);
                }
            }
            let lvl = ((m / max) * (RAMP.len() - 1) as f32).round() as usize;
            line.push(RAMP[lvl.min(RAMP.len() - 1)] as char);
        }
        println!("  {line}");
    }
}

fn main() -> Result<()> {
    let side = 50; // the paper's 50^2 = 2500-point discretisation
    let grid = data::positive_sphere_grid(side);
    let hists = data::corner_histograms(&grid, 0.2);

    // Remark 1: on the positive sphere the feature map is the identity,
    // K = X X^T with rank exactly 3 — r = d, no randomness.
    let fm = SphereLinearMap::new(3);
    let phi = fm.feature_matrix(&grid);
    let kernel = FactoredKernel::from_factors(phi.clone(), phi);
    println!(
        "kernel: {} (exact factorisation, per-apply flops {})",
        kernel.label(),
        kernel.flops_per_apply()
    );

    for (i, h) in hists.iter().enumerate() {
        heatmap(&format!("input histogram {} (corner {})", i, ["x", "y", "z"][i]), h, side);
    }

    let sw = Stopwatch::start();
    let bc = barycenter(&kernel, &hists.to_vec(), &[], &BarycenterConfig::default())?;
    println!(
        "\nIBP barycenter: {} iterations ({}) in {:.2}s",
        bc.iterations,
        if bc.converged { "converged" } else { "max-iters" },
        sw.elapsed_secs()
    );
    heatmap("barycenter (d)", &bc.p, side);

    // The paper's panel (e): softmax with temperature 1000 reveals that
    // mass concentrates where the arccos-geodesic midpoints lie.
    let mut sharp = bc.p.clone();
    softmax_inplace(&mut sharp, 1000.0);
    heatmap("softmax(T=1000) sharpened (e)", &sharp, side);

    Ok(())
}
