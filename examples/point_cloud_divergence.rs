//! Domain example: compare distributions across three of the paper's
//! workloads (2-D Gaussians, sphere bands, 28-dim Higgs-like), showing the
//! RF / Nys / Sin three-way contrast on each — including the regime where
//! Nyström loses positivity and errors out while RF keeps running.
//!
//! Run with: `cargo run --release --example point_cloud_divergence`

use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

fn run_case(name: &str, mu: &Measure, nu: &Measure, eps: f64, r: usize, rng: &mut Rng) {
    println!("\n=== {name} (n={}, d={}, eps={eps}, r={r}) ===", mu.len(), mu.dim());
    let cfg = SinkhornConfig { epsilon: eps, ..Default::default() };

    // Sin: dense ground truth.
    let sw = Stopwatch::start();
    let dense = DenseKernel::from_measures(mu, nu, eps);
    let truth = match sinkhorn(&dense, &mu.weights, &nu.weights, &cfg) {
        Ok(s) => {
            println!("  Sin: {:.6} ({:.0} ms)", s.objective, sw.elapsed_secs() * 1e3);
            Some(s.objective)
        }
        Err(e) => {
            println!("  Sin: FAILED ({e})");
            None
        }
    };

    // RF: positive features.
    let sw = Stopwatch::start();
    let map = GaussianFeatureMap::fit(mu, nu, eps, r, rng);
    let fk = FactoredKernel::from_measures(&map, mu, nu);
    match sinkhorn(&fk, &mu.weights, &nu.weights, &cfg) {
        Ok(s) => {
            let dev = truth
                .map(|t| {
                    format!("{:.2}", linear_sinkhorn::sinkhorn::deviation_score(t, s.objective))
                })
                .unwrap_or_else(|| "-".into());
            println!(
                "  RF : {:.6} ({:.0} ms, deviation {dev})",
                s.objective,
                sw.elapsed_secs() * 1e3
            );
        }
        Err(e) => println!("  RF : FAILED ({e})"),
    }

    // Nys: the low-rank baseline — may lose positivity.
    let sw = Stopwatch::start();
    let nk = NystromKernel::from_measures(mu, nu, eps, r.min(mu.len()), rng);
    match nk.validate_positive(rng, 3).and_then(|_| sinkhorn(&nk, &mu.weights, &nu.weights, &cfg)) {
        Ok(s) => {
            let dev = truth
                .map(|t| {
                    format!("{:.2}", linear_sinkhorn::sinkhorn::deviation_score(t, s.objective))
                })
                .unwrap_or_else(|| "-".into());
            println!(
                "  Nys: {:.6} ({:.0} ms, deviation {dev})",
                s.objective,
                sw.elapsed_secs() * 1e3
            );
        }
        Err(e) => println!("  Nys: FAILED ({e}) — the positivity failure RF avoids"),
    }
}

fn main() {
    let mut rng = Rng::seed_from(0);
    let n = 1500;

    // Workload 1: Fig-1 Gaussians, comfortable regularisation.
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    run_case("gaussian blobs, moderate eps", &mu, &nu, 0.5, 300, &mut rng);

    // Workload 2: same data, small eps — the regime that kills Nyström.
    run_case("gaussian blobs, small eps", &mu, &nu, 0.05, 300, &mut rng);

    // Workload 3: sphere bands (Fig. 2/3 geometry).
    let (sa, sb) = data::sphere_caps(n, &mut rng);
    run_case("sphere bands", &sa, &sb, 0.1, 300, &mut rng);

    // Workload 4: 28-dim Higgs-like (Fig. 5 substitute).
    let (sig, bkg) = data::higgs_pair(1000, &mut rng);
    run_case("higgs-like 28-dim", &sig, &bkg, 5.0, 500, &mut rng);
}
