//! Domain example: compare distributions across three of the paper's
//! workloads (2-D Gaussians, sphere bands, 28-dim Higgs-like), showing the
//! RF / Nys / Sin three-way contrast on each — including the regime where
//! Nyström loses positivity and errors out while RF keeps running. Every
//! contender is a different [`OtProblem`] plan on the same data.
//!
//! Run with: `cargo run --release --example point_cloud_divergence`

use linear_sinkhorn::prelude::*;

fn run_case(name: &str, mu: &Measure, nu: &Measure, eps: f64, r: usize, seed: u64) {
    println!("\n=== {name} (n={}, d={}, eps={eps}, r={r}) ===", mu.len(), mu.dim());

    // Sin: dense ground truth (plain domain: failures stay visible).
    let truth = match OtProblem::new(mu, nu)
        .epsilon(eps)
        .dense()
        .domain(DomainChoice::Plain)
        .solve()
    {
        Ok(s) => {
            println!("  Sin: {:.6} ({:.0} ms)", s.objective, s.wall_us as f64 / 1e3);
            Some(s.objective)
        }
        Err(e) => {
            println!("  Sin: FAILED ({e})");
            None
        }
    };
    let dev_of = |objective: f64| {
        truth
            .map(|t| format!("{:.2}", linear_sinkhorn::sinkhorn::deviation_score(t, objective)))
            .unwrap_or_else(|| "-".into())
    };

    // RF: positive features — the planner's factored backend.
    match OtProblem::new(mu, nu)
        .epsilon(eps)
        .rank(r)
        .domain(DomainChoice::Plain)
        .stabilized_factors(false)
        .seed(seed)
        .solve()
    {
        Ok(s) => println!(
            "  RF : {:.6} ({:.0} ms, deviation {})",
            s.objective,
            s.wall_us as f64 / 1e3,
            dev_of(s.objective)
        ),
        Err(e) => println!("  RF : FAILED ({e})"),
    }

    // Nys: the low-rank baseline — may lose positivity (the paper's
    // central contrast). Probe the exact kernel the plan will execute
    // (same seed => same landmark draw) with `validate_positive` first:
    // an indefinite approximation can corrupt the objective even when
    // Sinkhorn happens not to produce non-finite scalings, so waiting
    // for the solver's typed error alone would under-report the failure.
    // The probe kernel is deliberately built twice (once here, once
    // inside the planned solve): construction is O(n·rank·d + rank^3) —
    // milliseconds at example scale — and the planned API exposes no
    // pre-solve kernel hook.
    let nys_rank = r.min(mu.len());
    let nys_seed = seed ^ 0x4E59;
    let mut probe_rng = Rng::seed_from(nys_seed);
    let probe = NystromKernel::from_measures(mu, nu, eps, nys_rank, &mut probe_rng);
    let nys = probe.validate_positive(&mut probe_rng, 3).and_then(|_| {
        OtProblem::new(mu, nu).epsilon(eps).nystrom(nys_rank).seed(nys_seed).solve()
    });
    match nys {
        Ok(s) => println!(
            "  Nys: {:.6} ({:.0} ms, deviation {})",
            s.objective,
            s.wall_us as f64 / 1e3,
            dev_of(s.objective)
        ),
        Err(e) => println!("  Nys: FAILED ({e}) — the positivity failure RF avoids"),
    }
}

fn main() {
    let mut rng = Rng::seed_from(0);
    let n = 1500;

    // Workload 1: Fig-1 Gaussians, comfortable regularisation.
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    run_case("gaussian blobs, moderate eps", &mu, &nu, 0.5, 300, 1);

    // Workload 2: same data, small eps — the regime that kills Nyström.
    run_case("gaussian blobs, small eps", &mu, &nu, 0.05, 300, 2);

    // Workload 3: sphere bands (Fig. 2/3 geometry).
    let (sa, sb) = data::sphere_caps(n, &mut rng);
    run_case("sphere bands", &sa, &sb, 0.1, 300, 3);

    // Workload 4: 28-dim Higgs-like (Fig. 5 substitute).
    let (sig, bkg) = data::higgs_pair(1000, &mut rng);
    run_case("higgs-like 28-dim", &sig, &bkg, 5.0, 500, 4);
}
