//! Quickstart: compute a linear-time Sinkhorn divergence between two point
//! clouds in a dozen lines, and compare the factored (`RF`) path against
//! the dense (`Sin`) baseline on the same data.
//!
//! Run with: `cargo run --release --example quickstart`

use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

fn main() -> Result<()> {
    // 1. Two point clouds: N((1,1), I) vs N(0, 0.1 I) — the Fig. 1 setup.
    let mut rng = Rng::seed_from(0);
    let n = 3000;
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    let eps = 0.5;

    // 2. Positive random features for the Gaussian kernel (Lemma 1).
    //    `fit` reads the data radius R and sets the paper's q constant.
    let r = 600;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, r, &mut rng);
    println!("feature map: r = {r}, q = {:.3}, psi = {:.2e}", map.q, map.psi());

    // 3. The factored kernel K = Phi_x Phi_y^T — positive by construction,
    //    O(r(n+m)) per Sinkhorn iteration.
    let kernel = FactoredKernel::from_measures(&map, &mu, &nu);

    // 4. Solve regularised OT with Algorithm 1.
    let cfg = SinkhornConfig { epsilon: eps, ..Default::default() };
    let sw = Stopwatch::start();
    let sol = sinkhorn(&kernel, &mu.weights, &nu.weights, &cfg)?;
    let rf_time = sw.elapsed_secs();
    println!(
        "RF : W_eps ~= {:.6}  ({} iterations, {:.0} ms, marginal err {:.1e})",
        sol.objective,
        sol.iterations,
        rf_time * 1e3,
        sol.marginal_error
    );

    // 5. Dense baseline on the same data (the O(n^2) path the paper beats).
    let sw = Stopwatch::start();
    let dense = DenseKernel::from_measures(&mu, &nu, eps);
    let dsol = sinkhorn(&dense, &mu.weights, &nu.weights, &cfg)?;
    let sin_time = sw.elapsed_secs();
    println!(
        "Sin: W_eps  = {:.6}  ({} iterations, {:.0} ms)",
        dsol.objective,
        dsol.iterations,
        sin_time * 1e3
    );
    println!(
        "deviation score (100 = exact): {:.2}; speedup {:.1}x",
        linear_sinkhorn::sinkhorn::deviation_score(dsol.objective, sol.objective),
        sin_time / rf_time
    );

    // 6. The debiased Sinkhorn divergence (Eq. 2) — a proper discrepancy.
    let k_xx = FactoredKernel::from_measures(&map, &mu, &mu);
    let k_yy = FactoredKernel::from_measures(&map, &nu, &nu);
    let div = sinkhorn_divergence(&kernel, &k_xx, &k_yy, &mu.weights, &nu.weights, &cfg)?;
    println!("sinkhorn divergence(mu, nu) = {div:.6}");
    Ok(())
}
