//! Quickstart: compute a linear-time Sinkhorn divergence between two point
//! clouds through the planned `Problem → Plan → Solution` API, and compare
//! the factored (`RF`) plan against the dense (`Sin`) baseline on the same
//! data.
//!
//! Run with: `cargo run --release --example quickstart`

use linear_sinkhorn::prelude::*;

fn main() -> Result<()> {
    // 1. Two point clouds: N((1,1), I) vs N(0, 0.1 I) — the Fig. 1 setup.
    let mut rng = Rng::seed_from(0);
    let n = 3000;
    let (mu, nu) = data::gaussian_blobs(n, &mut rng);
    let eps = 0.5;

    // 2. Describe the problem; the planner picks the paper's positive-
    //    feature factored kernel (Lemma 1) and the numeric domain. One
    //    anchor draw serves every solve below (`with_feature_map` is the
    //    amortisation the service's feature cache automates).
    let r = 600;
    let map = GaussianFeatureMap::fit(&mu, &nu, eps, r, &mut rng);
    let problem = OtProblem::new(&mu, &nu).epsilon(eps).rank(r).with_feature_map(&map);
    let plan = problem.plan()?;
    println!("{}", plan.summary());

    // 3. Solve regularised OT through the plan — O(r(n+m)) per iteration.
    let sol = problem.solve_planned(&plan)?;
    println!(
        "RF : W_eps ~= {:.6}  ({} iterations, {:.1} ms, marginal err {:.1e}, arm {})",
        sol.objective,
        sol.iterations,
        sol.wall_us as f64 / 1e3,
        sol.marginal_error,
        sol.simd_arm
    );

    // 4. Dense baseline on the same data (the O(n^2) path the paper beats).
    let dsol = OtProblem::new(&mu, &nu).epsilon(eps).dense().solve()?;
    println!(
        "Sin: W_eps  = {:.6}  ({} iterations, {:.1} ms)",
        dsol.objective,
        dsol.iterations,
        dsol.wall_us as f64 / 1e3
    );
    println!(
        "deviation score (100 = exact): {:.2}; speedup {:.1}x",
        linear_sinkhorn::sinkhorn::deviation_score(dsol.objective, sol.objective),
        dsol.wall_us as f64 / sol.wall_us.max(1) as f64
    );

    // 5. The debiased Sinkhorn divergence (Eq. 2) — a proper discrepancy,
    //    three transport solves sharing one feature map.
    let report = problem.divergence_planned(&plan)?;
    println!("sinkhorn divergence(mu, nu) = {:.6}", report.divergence);

    // 6. Plans are serialisable decision records — ship them to a worker.
    println!("plan JSON: {}", plan.to_json());
    let decoded = Plan::from_json(&plan.to_json())?;
    assert_eq!(decoded, plan);
    Ok(())
}
