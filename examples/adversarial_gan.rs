//! END-TO-END DRIVER (Fig. 4 / Table 1 workload): train the adversarial-
//! kernel OT-GAN of paper §4 on a real small workload — the structured
//! synthetic image corpus — for a few hundred steps, logging the Sinkhorn-
//! divergence loss curve, then reproduce the Table-1 kernel probe
//! (learned kernel on image-vs-image, image-vs-noise, noise-vs-noise).
//!
//! This exercises the full stack: data pipeline -> generator/embedding MLPs
//! -> learned positive feature map -> factored kernels -> linear-time
//! Sinkhorn -> Prop-3.2 envelope gradients -> Adam, with per-step metrics.
//! The run is recorded in EXPERIMENTS.md.
//!
//! Run with: `cargo run --release --example adversarial_gan -- [--steps 300]`

use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::config::GanConfig;
use linear_sinkhorn::gan::GanTrainer;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;

fn main() -> Result<()> {
    let args = ArgSpec::new("adversarial_gan", "end-to-end OT-GAN training driver")
        .opt("steps", "300", "generator steps")
        .opt("batch", "256", "minibatch size s (linear Sinkhorn makes this cheap)")
        .opt("features", "64", "learned positive feature count r")
        .opt("side", "8", "image side in pixels")
        .opt("eps", "1.0", "Sinkhorn regularisation (paper: 1.0)")
        .opt("seed", "0", "RNG seed")
        .opt("csv", "", "optional CSV path for the loss curve")
        .parse();

    let side = args.get_usize("side");
    let dim = side * side;
    let cfg = GanConfig {
        steps: args.get_usize("steps"),
        batch_size: args.get_usize("batch"),
        num_features: args.get_usize("features"),
        epsilon: args.get_f64("eps"),
        seed: args.get_u64("seed"),
        ..Default::default()
    };

    println!(
        "adversarial-kernel OT-GAN: {dim}-dim images, batch s={}, r={}, eps={}, {} steps",
        cfg.batch_size, cfg.num_features, cfg.epsilon, cfg.steps
    );

    // Data pipeline: structured image corpus (the paper's CIFAR stand-in;
    // see EXPERIMENTS.md §GAN training runs) + held-out noise batch for
    // the Table-1 probe.
    let mut rng = Rng::seed_from(cfg.seed);
    let corpus = data::image_corpus(cfg.batch_size * 8, side, &mut rng);
    let mut trainer = GanTrainer::new(dim, cfg.clone(), &mut rng);
    let mut batch_rng = Rng::seed_from(cfg.seed ^ 0x5EED);

    let sw = Stopwatch::start();
    let mut curve: Vec<(usize, f64)> = Vec::new();
    for step in 0..cfg.steps {
        let idx = batch_rng.sample_indices(corpus.rows(), cfg.batch_size);
        let real = Mat::from_fn(cfg.batch_size, dim, |i, j| corpus[(idx[i], j)]);
        let rep = trainer.train_step(step, &real)?;
        curve.push((step, rep.divergence));
        if step % 20 == 0 || step + 1 == cfg.steps {
            println!(
                "step {:>4}  loss(divergence) {:>11.6}  w_xy {:>9.5}  [{:.1}s elapsed]",
                step,
                rep.divergence,
                rep.w_xy,
                sw.elapsed_secs()
            );
        }
    }

    // Loss-curve summary: compare first-decile and last-decile means.
    let decile = (curve.len() / 10).max(1);
    let head: f64 = curve[..decile].iter().map(|x| x.1).sum::<f64>() / decile as f64;
    let tail: f64 =
        curve[curve.len() - decile..].iter().map(|x| x.1).sum::<f64>() / decile as f64;
    println!(
        "\nloss curve: first-decile mean {head:.6} -> last-decile mean {tail:.6} ({})",
        if tail < head { "improved" } else { "did not improve" }
    );

    let csv = args.get_str("csv");
    if !csv.is_empty() {
        let mut text = String::from("step,divergence\n");
        for (s, d) in &curve {
            text.push_str(&format!("{s},{d}\n"));
        }
        std::fs::write(csv, text)?;
        println!("loss curve written to {csv}");
    }

    // Table-1 probe: the learned kernel should assign much higher values
    // within the image manifold than between images and noise.
    let mut probe_rng = Rng::seed_from(999);
    let imgs = data::image_corpus(5, side, &mut probe_rng);
    let noise = data::noise_images(5, side, &mut probe_rng);
    let k_ii = trainer.mean_kernel(&imgs, &imgs);
    let k_in = trainer.mean_kernel(&imgs, &noise);
    let k_nn = trainer.mean_kernel(&noise, &noise);
    println!("\nTable-1 probe (mean learned kernel over 5x5 samples):");
    println!("  k(image, image) = {k_ii:.4e}");
    println!("  k(image, noise) = {k_in:.4e}");
    println!("  k(noise, noise) = {k_nn:.4e}");
    println!(
        "  structure captured: k_ii/k_in = {:.2} (paper reports a large ratio)",
        k_ii / k_in.max(1e-30)
    );

    // ASCII peek at three generated "images".
    let samples = trainer.generate(3);
    const RAMP: &[u8] = b" .:-=+*#%@";
    for s in 0..3 {
        println!("\ngenerated sample {s}:");
        for i in 0..side {
            let mut line = String::new();
            for j in 0..side {
                let v = samples[(s, i * side + j)].clamp(0.0, 1.0);
                line.push(RAMP[(v * (RAMP.len() - 1) as f32).round() as usize] as char);
            }
            println!("  {line}");
        }
    }
    Ok(())
}
