//! Sinkhorn-divergence gradient flow: morph one point cloud into another
//! by descending Wbar(mu(X), nu) on the support locations X — the
//! application of Prop 3.2's differentiability that the paper contrasts
//! against Nyström (not differentiable at the inputs).
//!
//! Every step is linear-time in the cloud sizes thanks to the factored
//! kernel. Prints the divergence trace and ASCII scatter plots.
//!
//! Run with: `cargo run --release --example gradient_flow`

use linear_sinkhorn::cli::ArgSpec;
use linear_sinkhorn::metrics::Stopwatch;
use linear_sinkhorn::prelude::*;
use linear_sinkhorn::sinkhorn::gradient_flow_step;

/// Coarse ASCII scatter of two clouds (o = source, x = target).
fn scatter(mu: &Measure, nu: &Measure) {
    const W: usize = 64;
    const H: usize = 20;
    let mut lo = [f32::INFINITY; 2];
    let mut hi = [f32::NEG_INFINITY; 2];
    for m in [mu, nu] {
        for i in 0..m.len() {
            for c in 0..2 {
                lo[c] = lo[c].min(m.points[(i, c)]);
                hi[c] = hi[c].max(m.points[(i, c)]);
            }
        }
    }
    let mut grid = vec![b' '; W * H];
    let mut plot = |m: &Measure, ch: u8| {
        for i in 0..m.len() {
            let x = ((m.points[(i, 0)] - lo[0]) / (hi[0] - lo[0]).max(1e-9) * (W - 1) as f32)
                as usize;
            let y = ((m.points[(i, 1)] - lo[1]) / (hi[1] - lo[1]).max(1e-9) * (H - 1) as f32)
                as usize;
            let cell = &mut grid[y * W + x];
            *cell = if *cell == b' ' || *cell == ch { ch } else { b'#' };
        }
    };
    plot(nu, b'x');
    plot(mu, b'o');
    for row in grid.chunks(W).rev() {
        println!("  {}", String::from_utf8_lossy(row));
    }
}

fn main() -> Result<()> {
    let args = ArgSpec::new("gradient_flow", "Sinkhorn-divergence flow on point locations")
        .opt("n", "300", "points per cloud")
        .opt("steps", "60", "flow steps")
        .opt("eps", "0.5", "regularisation")
        .opt("features", "600", "positive random features r")
        .opt("lr", "0.8", "flow step size")
        .opt("seed", "0", "seed")
        .parse();

    let mut rng = Rng::seed_from(args.get_u64("seed"));
    let n = args.get_usize("n");
    let eps = args.get_f64("eps");

    // Source: tight blob at the origin. Target: ring of radius 2.
    let mut mu = data::gaussian_cloud(n, 2, 0.0, 0.25, &mut rng);
    let ring = Mat::from_fn(n, 2, |i, c| {
        let t = i as f64 / n as f64 * std::f64::consts::TAU;
        let rr = 2.0 + 0.05 * rng.normal();
        (if c == 0 { rr * t.cos() } else { rr * t.sin() }) as f32
    });
    let nu = Measure::uniform(ring);

    // One anchor draw reused for the whole flow (radius covers both clouds
    // plus travel slack).
    let map = GaussianFeatureMap::new(eps, 4.0, 2, args.get_usize("features"), &mut rng);
    let cfg = SinkhornConfig {
        epsilon: eps,
        max_iters: 1500,
        tol: 1e-6,
        check_every: 10,
        ..Default::default()
    };

    println!("before:");
    scatter(&mu, &nu);

    let lr = args.get_f64("lr") as f32;
    let sw = Stopwatch::start();
    for step in 0..args.get_usize("steps") {
        let d = gradient_flow_step(&map, &mut mu, &nu, &cfg, lr)?;
        if step % 10 == 0 {
            println!("step {step:>3}: divergence {d:.6}");
        }
    }
    let final_div = gradient_flow_step(&map, &mut mu, &nu, &cfg, 0.0)?;
    println!(
        "final divergence {final_div:.6} after {} steps in {:.1}s",
        args.get_usize("steps"),
        sw.elapsed_secs()
    );

    println!("after:");
    scatter(&mu, &nu);
    Ok(())
}
